//! Deterministic ablation harness + committed perf registry (DESIGN.md
//! §17): the repo's answer to "perf trajectories evaporate between CI
//! runs".
//!
//! A declarative plan (`ablate/*.toml`) pins a cartesian grid of
//! op kind x variant x schedule x stage depth x exec path x model kind
//! plus the seeds/steps/rows every cell trains with. [`run_plan`] expands
//! the grid, runs each cell through the native [`TrainEngine`] under a
//! pinned single-thread budget, and extracts two classes of KPI:
//!
//! - **exact** KPIs (`loss`, `acc`, `param_count`, `flops_per_row`,
//!   `allocs_per_step`): bit-reproducible under pinned seeds/threads —
//!   the same plan run twice must produce byte-identical values
//!   ([`exact_rows`]), and `--check` compares them against the registry
//!   at zero tolerance unless the plan declares a band.
//! - **measured** KPIs (`ns_per_row`, `rows_per_sec`): wall-clock
//!   figures, reported for the record but only gated when the plan
//!   declares an explicit `[tolerance.<kpi>]` band (machines differ;
//!   bands are one-sided in the regression direction).
//!
//! Results append to a committed `registry/<plan>.csv` — append-only,
//! schema-versioned, each row stamped with git SHA, exec backend, and
//! the FNV-64 hash of the plan's canonical text, so a tolerance edit or
//! axis change can never be confused with the run it gated.
//!
//! The module also owns [`Gates`]: the declarative home of every bench
//! `--check` threshold (`ablate/gates.toml`). The bench binaries load it
//! instead of carrying hardcoded constants, so the whole perf contract
//! is reviewable in one file.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use spm_core::models::api::{build_model, Model, ModelCfg, ModelKind};
use spm_core::ops::{backend, LinearCfg, LinearKind, SpmExec};
use spm_core::pairing::Schedule;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;

use crate::allocs;
use crate::bail;
use crate::bench_args::{env_exec, json_header, json_num};
use crate::config::{line_of, line_of_section, parse_toml, Value};
use crate::error::{Context, Result};
use crate::train::{TrainBatch, TrainEngine};

/// Version of the `registry/*.csv` layout, stamped both in the file's
/// magic first line and in every row. Bump when columns change.
pub const REGISTRY_SCHEMA_VERSION: u32 = 1;

/// First line of every registry file; the loader refuses anything else.
pub const REGISTRY_MAGIC: &str = "# spm-ablate-registry v1";

// ---------------------------------------------------------------------------
// KPI schema
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KpiClass {
    /// Bit-reproducible under pinned seeds/threads; gated at zero
    /// tolerance by default.
    Exact,
    /// Wall-clock; report-only unless the plan declares a band.
    Measured,
}

/// One column of the KPI vector.
pub struct KpiSpec {
    pub name: &'static str,
    pub class: KpiClass,
    /// Which drift direction is a regression: `1` = larger is worse,
    /// `-1` = smaller is worse, `0` = any drift beyond the band fails
    /// (identity KPIs like param counts).
    pub worse: i8,
}

/// The KPI columns, in registry/JSON order.
pub const KPIS: [KpiSpec; 7] = [
    KpiSpec { name: "loss", class: KpiClass::Exact, worse: 1 },
    KpiSpec { name: "acc", class: KpiClass::Exact, worse: -1 },
    KpiSpec { name: "param_count", class: KpiClass::Exact, worse: 0 },
    KpiSpec { name: "flops_per_row", class: KpiClass::Exact, worse: 0 },
    KpiSpec { name: "allocs_per_step", class: KpiClass::Exact, worse: 1 },
    KpiSpec { name: "ns_per_row", class: KpiClass::Measured, worse: 1 },
    KpiSpec { name: "rows_per_sec", class: KpiClass::Measured, worse: -1 },
];

fn kpi_index(name: &str) -> Option<usize> {
    KPIS.iter().position(|k| k.name == name)
}

// ---------------------------------------------------------------------------
// Plan
// ---------------------------------------------------------------------------

/// Per-KPI tolerance band: a fresh value may drift past the registry
/// baseline by at most `abs + rel * |baseline|` in the KPI's regression
/// direction before `--check` fails.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tolerance {
    pub abs: f64,
    pub rel: f64,
}

/// One value of the `exec` axis: a pinned path, or "env" — resolved from
/// `SPM_EXEC` at run time so the same committed plan exercises each CI
/// matrix leg.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExecAxis {
    Env,
    Fixed(SpmExec),
}

impl ExecAxis {
    fn parse(s: &str) -> Option<ExecAxis> {
        if s == "env" {
            Some(ExecAxis::Env)
        } else {
            SpmExec::parse(s).map(ExecAxis::Fixed)
        }
    }

    fn name(&self) -> &str {
        match self {
            ExecAxis::Env => "env",
            ExecAxis::Fixed(e) => e.name(),
        }
    }
}

/// A parsed `ablate/*.toml` plan: the pinned experiment shape plus the
/// axes the driver cartesian-expands. See DESIGN.md §17 for the format.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub name: String,
    pub seed: u64,
    /// Microbatches per cell (one optimizer step each; R=1, accum=1).
    pub steps: usize,
    /// Rows per microbatch.
    pub rows: usize,
    /// Mixing width every cell's model is built at.
    pub n: usize,
    pub classes: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub ops: Vec<LinearKind>,
    pub variants: Vec<Variant>,
    pub schedules: Vec<Schedule>,
    /// Explicit stage depths; empty = the paper default `log2(n)` only.
    pub stages: Vec<usize>,
    pub execs: Vec<ExecAxis>,
    pub models: Vec<ModelKind>,
    /// Declared `[tolerance.<kpi>]` bands, by KPI name.
    pub tolerances: BTreeMap<String, Tolerance>,
}

impl Default for Plan {
    fn default() -> Self {
        Plan {
            name: String::new(),
            seed: 7,
            steps: 0,
            rows: 0,
            n: 0,
            classes: 4,
            heads: 2,
            seq_len: 2,
            ops: vec![LinearKind::Spm],
            variants: vec![Variant::General],
            schedules: vec![Schedule::Butterfly],
            stages: Vec::new(),
            execs: vec![ExecAxis::Env],
            models: vec![ModelKind::Mlp],
            tolerances: BTreeMap::new(),
        }
    }
}

impl Plan {
    /// Parse + validate a plan document. Every semantic error carries the
    /// 1-based source line of the offending key.
    pub fn parse(text: &str) -> Result<Plan> {
        let doc = parse_toml(text)?;
        if let Some(map) = doc.get("") {
            if let Some(key) = map.keys().next() {
                bail!(
                    "line {}: top-level key '{key}' — plan keys live under [plan], \
                     [axes], or [tolerance.<kpi>]",
                    line_of(text, "", key)
                );
            }
        }
        for section in doc.keys() {
            match section.as_str() {
                "" | "plan" | "axes" => {}
                s => {
                    let kpi = s.strip_prefix("tolerance.").unwrap_or("");
                    if kpi.is_empty() || kpi_index(kpi).is_none() {
                        bail!(
                            "line {}: unknown section [{s}] (expected [plan], [axes], \
                             or [tolerance.<kpi>] with a KPI from {:?})",
                            line_of_section(text, s),
                            KPIS.map(|k| k.name)
                        );
                    }
                }
            }
        }

        let mut plan = Plan::default();

        let pmap = doc.get("plan").context("plan is missing its [plan] section")?;
        for key in pmap.keys() {
            if !["name", "seed", "steps", "rows", "n", "classes", "heads", "seq_len"]
                .contains(&key.as_str())
            {
                bail!("line {}: unknown [plan] key '{key}'", line_of(text, "plan", key));
            }
        }
        let name = pmap
            .get("name")
            .and_then(Value::as_str)
            .context("[plan] name (a string) is required")?;
        if name.is_empty()
            || !name.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_' || b == b'-')
        {
            bail!(
                "line {}: [plan] name '{name}' must be non-empty [a-z0-9_-] (it names \
                 the registry file)",
                line_of(text, "plan", "name")
            );
        }
        plan.name = name.to_string();
        for (key, dst, min) in [
            ("steps", &mut plan.steps, 1usize),
            ("rows", &mut plan.rows, 1),
            ("n", &mut plan.n, 2),
            ("classes", &mut plan.classes, 2),
            ("heads", &mut plan.heads, 1),
            ("seq_len", &mut plan.seq_len, 1),
        ] {
            if let Some(v) = pmap.get(key) {
                let u = v.as_usize().with_context(|| {
                    format!(
                        "line {}: [plan] {key} must be a non-negative int",
                        line_of(text, "plan", key)
                    )
                })?;
                if u < min {
                    bail!("line {}: [plan] {key} must be >= {min}", line_of(text, "plan", key));
                }
                *dst = u;
            }
        }
        for key in ["steps", "rows", "n"] {
            if pmap.get(key).is_none() {
                bail!("[plan] {key} (an int) is required — plans pin their workload");
            }
        }
        if let Some(v) = pmap.get("seed") {
            plan.seed = v.as_usize().with_context(|| {
                format!(
                    "line {}: [plan] seed must be a non-negative int",
                    line_of(text, "plan", "seed")
                )
            })? as u64;
        }

        if let Some(amap) = doc.get("axes") {
            for key in amap.keys() {
                if !["op", "variant", "schedule", "stages", "exec", "model"].contains(&key.as_str())
                {
                    bail!("line {}: unknown [axes] key '{key}'", line_of(text, "axes", key));
                }
            }
            let strings = |key: &str| -> Result<Option<Vec<String>>> {
                let Some(v) = amap.get(key) else { return Ok(None) };
                let items = v.as_list().with_context(|| {
                    format!(
                        "line {}: [axes] {key} must be a [\"..\"] list",
                        line_of(text, "axes", key)
                    )
                })?;
                if items.is_empty() {
                    bail!("line {}: [axes] {key} must not be empty", line_of(text, "axes", key));
                }
                let mut out = Vec::with_capacity(items.len());
                for item in items {
                    out.push(
                        item.as_str()
                            .with_context(|| {
                                format!(
                                    "line {}: [axes] {key} elements must be strings",
                                    line_of(text, "axes", key)
                                )
                            })?
                            .to_string(),
                    );
                }
                Ok(Some(out))
            };
            if let Some(names) = strings("op")? {
                plan.ops = Vec::new();
                for s in names {
                    plan.ops.push(LinearKind::parse(&s).with_context(|| {
                        format!(
                            "line {}: [axes] op '{s}' is not an op kind",
                            line_of(text, "axes", "op")
                        )
                    })?);
                }
            }
            if let Some(names) = strings("variant")? {
                plan.variants = Vec::new();
                for s in names {
                    plan.variants.push(Variant::parse(&s).with_context(|| {
                        format!(
                            "line {}: [axes] variant '{s}' is not a variant",
                            line_of(text, "axes", "variant")
                        )
                    })?);
                }
            }
            if let Some(names) = strings("schedule")? {
                plan.schedules = Vec::new();
                for s in names {
                    plan.schedules.push(Schedule::parse(&s).with_context(|| {
                        format!(
                            "line {}: [axes] schedule '{s}' is not a pairing schedule",
                            line_of(text, "axes", "schedule")
                        )
                    })?);
                }
            }
            if let Some(names) = strings("exec")? {
                plan.execs = Vec::new();
                for s in names {
                    plan.execs.push(ExecAxis::parse(&s).with_context(|| {
                        format!(
                            "line {}: [axes] exec '{s}' is not an exec path \
                             (rowwise/fused/simd/env)",
                            line_of(text, "axes", "exec")
                        )
                    })?);
                }
            }
            if let Some(names) = strings("model")? {
                plan.models = Vec::new();
                for s in names {
                    plan.models.push(ModelKind::parse(&s).with_context(|| {
                        format!(
                            "line {}: [axes] model '{s}' is not a model kind",
                            line_of(text, "axes", "model")
                        )
                    })?);
                }
            }
            if let Some(v) = amap.get("stages") {
                let items = v.as_list().with_context(|| {
                    format!(
                        "line {}: [axes] stages must be an int list",
                        line_of(text, "axes", "stages")
                    )
                })?;
                if items.is_empty() {
                    bail!(
                        "line {}: [axes] stages must not be empty (omit the key for \
                         the log2(n) default)",
                        line_of(text, "axes", "stages")
                    );
                }
                plan.stages = Vec::new();
                for item in items {
                    let l = item.as_usize().with_context(|| {
                        format!(
                            "line {}: [axes] stages elements must be non-negative ints",
                            line_of(text, "axes", "stages")
                        )
                    })?;
                    if l == 0 {
                        bail!(
                            "line {}: [axes] stages must be >= 1",
                            line_of(text, "axes", "stages")
                        );
                    }
                    plan.stages.push(l);
                }
            }
        }

        for (section, map) in &doc {
            let Some(kpi) = section.strip_prefix("tolerance.") else { continue };
            let mut tol = Tolerance { abs: 0.0, rel: 0.0 };
            for (key, dst) in [("abs", &mut tol.abs), ("rel", &mut tol.rel)] {
                if let Some(v) = map.get(key) {
                    let f = v.as_f64().with_context(|| {
                        format!(
                            "line {}: [tolerance.{kpi}] {key} must be a number",
                            line_of(text, section, key)
                        )
                    })?;
                    if !(f.is_finite() && f >= 0.0) {
                        bail!(
                            "line {}: [tolerance.{kpi}] {key} must be a finite \
                             non-negative number",
                            line_of(text, section, key)
                        );
                    }
                    *dst = f;
                }
            }
            for key in map.keys() {
                if key != "abs" && key != "rel" {
                    bail!(
                        "line {}: unknown [tolerance.{kpi}] key '{key}' (abs/rel only)",
                        line_of(text, section, key)
                    );
                }
            }
            plan.tolerances.insert(kpi.to_string(), tol);
        }

        if plan.models.contains(&ModelKind::Attention) && plan.n % plan.heads != 0 {
            bail!(
                "line {}: [plan] heads = {} must divide n = {} (the model axis \
                 includes attention)",
                line_of(text, "plan", "heads").max(line_of(text, "plan", "n")),
                plan.heads,
                plan.n
            );
        }
        Ok(plan)
    }

    pub fn load(path: &Path) -> Result<Plan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan {}", path.display()))?;
        Plan::parse(&text).with_context(|| format!("plan {}", path.display()))
    }

    /// Canonical re-rendering: parseable, key-ordered, default axes made
    /// explicit. [`Plan::hash`] is the FNV-64 of exactly this text, so a
    /// reformatted-but-equivalent plan file keeps its registry rows.
    pub fn canonical(&self) -> String {
        let join = |names: Vec<String>| -> String {
            let quoted: Vec<String> = names.into_iter().map(|s| format!("\"{s}\"")).collect();
            format!("[{}]", quoted.join(", "))
        };
        let mut s = String::new();
        s.push_str("[plan]\n");
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("steps = {}\n", self.steps));
        s.push_str(&format!("rows = {}\n", self.rows));
        s.push_str(&format!("n = {}\n", self.n));
        s.push_str(&format!("classes = {}\n", self.classes));
        s.push_str(&format!("heads = {}\n", self.heads));
        s.push_str(&format!("seq_len = {}\n", self.seq_len));
        s.push_str("\n[axes]\n");
        s.push_str(&format!(
            "op = {}\n",
            join(self.ops.iter().map(|k| k.name().to_string()).collect())
        ));
        s.push_str(&format!(
            "variant = {}\n",
            join(self.variants.iter().map(|v| v.name().to_string()).collect())
        ));
        s.push_str(&format!(
            "schedule = {}\n",
            join(self.schedules.iter().map(|v| v.name().to_string()).collect())
        ));
        if !self.stages.is_empty() {
            let stages: Vec<String> = self.stages.iter().map(|l| l.to_string()).collect();
            s.push_str(&format!("stages = [{}]\n", stages.join(", ")));
        }
        s.push_str(&format!(
            "exec = {}\n",
            join(self.execs.iter().map(|e| e.name().to_string()).collect())
        ));
        s.push_str(&format!(
            "model = {}\n",
            join(self.models.iter().map(|m| m.name().to_string()).collect())
        ));
        for (kpi, tol) in &self.tolerances {
            s.push_str(&format!("\n[tolerance.{kpi}]\nabs = {}\nrel = {}\n", tol.abs, tol.rel));
        }
        s
    }

    /// 16-hex-digit FNV-64 of [`Plan::canonical`]; stamps every registry
    /// row so baselines never survive a plan change unnoticed.
    pub fn hash(&self) -> String {
        format!("{:016x}", fnv1a64(self.canonical().as_bytes()))
    }

    /// The effective band for a KPI: the declared one, zero for exact
    /// KPIs, `None` (ungated) for undeclared measured KPIs.
    fn tolerance_for(&self, spec: &KpiSpec) -> Option<Tolerance> {
        match self.tolerances.get(spec.name) {
            Some(t) => Some(*t),
            None => match spec.class {
                KpiClass::Exact => Some(Tolerance { abs: 0.0, rel: 0.0 }),
                KpiClass::Measured => None,
            },
        }
    }
}

pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Cells
// ---------------------------------------------------------------------------

/// One point of the expanded grid. Dense cells normalize the SPM-only
/// axes (variant/schedule/stages) so the grid dedupes to one dense cell
/// per (model, exec).
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub model: ModelKind,
    pub op: LinearKind,
    pub variant: Variant,
    pub schedule: Schedule,
    /// None = the paper default log2(n).
    pub stages: Option<usize>,
    pub exec: SpmExec,
}

impl Cell {
    /// Stable identity WITHOUT the exec backend (the registry keeps exec
    /// in its own column). Space-separated: cells embed into CSV rows.
    /// Kinds only mention the axes they actually consume: dense, lowrank
    /// and blockshuffle are schedule-free; butterfly pins its schedule so
    /// only the stage depth remains free (DESIGN.md §19).
    pub fn id(&self) -> String {
        match self.op {
            LinearKind::Dense | LinearKind::LowRank | LinearKind::BlockShuffle => {
                format!("model={} op={}", self.model.name(), self.op.name())
            }
            LinearKind::Butterfly => format!(
                "model={} op=butterfly stages={}",
                self.model.name(),
                self.stages.map_or_else(|| "default".to_string(), |l| l.to_string()),
            ),
            LinearKind::Spm => format!(
                "model={} op=spm variant={} schedule={} stages={}",
                self.model.name(),
                self.variant.name(),
                self.schedule.name(),
                self.stages.map_or_else(|| "default".to_string(), |l| l.to_string()),
            ),
        }
    }

    /// Identity including the exec backend (progress lines, skip notes).
    pub fn key(&self) -> String {
        format!("{} exec={}", self.id(), self.exec.name())
    }

    fn to_model_cfg(&self, plan: &Plan) -> ModelCfg {
        // lowrank/blockshuffle knobs stay at their equal-budget defaults:
        // the zoo plan compares STRUCTURE at matched parameter spend
        let mut op = match self.op {
            LinearKind::Dense => LinearCfg::dense(plan.n),
            LinearKind::Spm => LinearCfg::spm(plan.n, self.variant).with_schedule(self.schedule),
            LinearKind::LowRank => LinearCfg::lowrank(plan.n),
            LinearKind::BlockShuffle => LinearCfg::blockshuffle(plan.n),
            LinearKind::Butterfly => LinearCfg::butterfly(plan.n),
        };
        if let Some(l) = self.stages {
            op = op.with_stages(l);
        }
        ModelCfg::new(self.model, op.with_seed(plan.seed))
            .with_classes(plan.classes)
            .with_heads(plan.heads)
            .with_seq_len(plan.seq_len)
            .with_seed(plan.seed ^ 0xC1A55)
            .with_exec(self.exec)
    }
}

/// Cartesian-expand the plan's axes, resolving `exec = "env"` against
/// `env_exec` and deduping cells the grid collapses (dense/lowrank/
/// blockshuffle ops ignore variant/schedule/stages, butterfly ignores
/// variant/schedule; duplicate axis values fold away).
pub fn expand(plan: &Plan, env_exec: SpmExec) -> Vec<Cell> {
    let mut out: Vec<Cell> = Vec::new();
    let mut seen: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    let stages: Vec<Option<usize>> = if plan.stages.is_empty() {
        vec![None]
    } else {
        plan.stages.iter().map(|&l| Some(l)).collect()
    };
    for &model in &plan.models {
        for &op in &plan.ops {
            for &variant in &plan.variants {
                for &schedule in &plan.schedules {
                    for &stage in &stages {
                        for &exec_axis in &plan.execs {
                            let exec = match exec_axis {
                                ExecAxis::Env => env_exec,
                                ExecAxis::Fixed(e) => e,
                            };
                            let cell = match op {
                                // schedule-free kinds normalize every SPM-only
                                // axis so the grid dedupes to one cell per
                                // (model, exec)
                                LinearKind::Dense
                                | LinearKind::LowRank
                                | LinearKind::BlockShuffle => Cell {
                                    model,
                                    op,
                                    variant: Variant::General,
                                    schedule: Schedule::Butterfly,
                                    stages: None,
                                    exec,
                                },
                                // butterfly pins variant/schedule; only the
                                // stage depth stays a live axis
                                LinearKind::Butterfly => Cell {
                                    model,
                                    op,
                                    variant: Variant::General,
                                    schedule: Schedule::Butterfly,
                                    stages: stage,
                                    exec,
                                },
                                LinearKind::Spm => {
                                    Cell { model, op, variant, schedule, stages: stage, exec }
                                }
                            };
                            if seen.insert(cell.key()) {
                                out.push(cell);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Running
// ---------------------------------------------------------------------------

/// One cell's KPI vector, in [`KPIS`] order.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: Cell,
    pub kpis: [f64; KPIS.len()],
}

/// What a full plan run produced.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub plan_name: String,
    pub plan_hash: String,
    pub git_sha: String,
    pub cells: Vec<CellResult>,
    /// Cells that could not run on this machine (an explicit `"simd"`
    /// axis value without the backend) — named, never silent.
    pub skipped: Vec<String>,
}

/// A deterministic kind-aware microbatch stream (the same recipe as the
/// TrainEngine integration tests): learnable labels derived from the
/// features; attention trains on value targets.
pub fn cell_batches(model: &dyn Model, count: usize, rows: usize, seed: u64) -> Vec<TrainBatch> {
    let d = model.d_in();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| match model.kind() {
            ModelKind::Attention => {
                let x = Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0));
                let t = x.clone();
                TrainBatch::values(x, t)
            }
            ModelKind::CharLm => {
                let x = Mat::from_vec(
                    rows,
                    d,
                    (0..rows * d).map(|i| 97.0 + (i % 3) as f32).collect(),
                );
                let y: Vec<u32> = (0..rows).map(|r| 97 + (x.at(r, 0) as u32) % 2).collect();
                TrainBatch::labels(x, y)
            }
            _ => {
                let x = Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0));
                let y: Vec<u32> =
                    (0..rows).map(|r| u32::from(x.at(r, 0) > x.at(r, 1))).collect();
                TrainBatch::labels(x, y)
            }
        })
        .collect()
}

/// Train + measure one cell: `plan.steps` single-microbatch optimizer
/// steps on an R=1 engine under a pinned 1-thread budget, held-out
/// evaluation, then a warmed steady-state allocation probe. Fully
/// deterministic in the exact KPIs.
pub fn run_cell(plan: &Plan, cell: &Cell) -> Result<CellResult> {
    let cfg = cell.to_model_cfg(plan);
    let probe = build_model(&cfg);
    let train = cell_batches(probe.as_ref(), plan.steps, plan.rows, plan.seed ^ 0xDA7A);
    let eval = cell_batches(probe.as_ref(), 1, plan.rows, plan.seed ^ 0xEAA1);
    drop(probe);

    let mut engine = TrainEngine::from_cfg(&cfg, 1).with_threads_per_replica(1);
    let report = engine.train_epoch(&train);
    let (loss, acc) = {
        let model = engine.model();
        model.evaluate(&eval[0].x, &eval[0].target.as_target())
    };
    if !loss.is_finite() {
        bail!("cell {} diverged: eval loss = {loss}", cell.key());
    }
    let param_count = engine.model().param_count();
    let flops = engine.model().flops_per_row();

    // steady-state allocations per optimizer step: warm the step path
    // (growth allocations happen once), then count. Meaningful only in
    // binaries that install `CountingAlloc`; 0 elsewhere — either way
    // deterministic, which is what the exact-KPI contract needs.
    let probe_group = &train[..1];
    engine.step(probe_group);
    engine.step(probe_group);
    let allocs_per_step = allocs::allocs_per_iter(2, || {
        engine.step(probe_group);
    });

    let ns_per_row =
        if report.rows_per_sec > 0.0 { 1e9 / report.rows_per_sec } else { f64::INFINITY };
    Ok(CellResult {
        cell: cell.clone(),
        kpis: [
            loss as f64,
            acc as f64,
            param_count as f64,
            flops as f64,
            allocs_per_step,
            ns_per_row,
            report.rows_per_sec,
        ],
    })
}

/// Expand + run every cell of the plan on this machine. `SPM_EXEC=simd`
/// without the backend is a hard error (the CI matrix contract — a
/// silent downgrade would stamp wrong-backend rows); an explicit
/// `"simd"` axis value merely skips, so committed plans stay portable.
pub fn run_plan(plan: &Plan) -> Result<PlanReport> {
    let env = env_exec();
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && !backend::simd_available() {
        bail!("SPM_EXEC=simd but the vectorized backend is unavailable on this build/machine");
    }
    let mut report = PlanReport {
        plan_name: plan.name.clone(),
        plan_hash: plan.hash(),
        git_sha: git_sha(),
        cells: Vec::new(),
        skipped: Vec::new(),
    };
    for cell in expand(plan, env) {
        if cell.exec == SpmExec::Simd && !backend::simd_available() {
            report.skipped.push(cell.key());
            continue;
        }
        report.cells.push(run_cell(plan, &cell)?);
    }
    Ok(report)
}

/// One line per cell holding its identity and EXACT KPIs, serialized via
/// Rust's shortest-round-trip float `Display` — two runs of the same
/// plan must produce byte-identical vectors (the `--check` determinism
/// gate and the pinned-seed tests compare exactly these).
pub fn exact_rows(report: &PlanReport) -> Vec<String> {
    report
        .cells
        .iter()
        .map(|c| {
            let mut s = format!("{} exec={}", c.cell.id(), c.cell.exec.name());
            for (spec, v) in KPIS.iter().zip(&c.kpis) {
                if spec.class == KpiClass::Exact {
                    s.push_str(&format!(" {}={v}", spec.name));
                }
            }
            s
        })
        .collect()
}

/// The stable-schema JSON artifact (`ABLATE_<plan>.json`).
pub fn report_json(plan: &Plan, report: &PlanReport) -> String {
    let mut s = json_header("ablate");
    s.push_str(&format!("  \"plan\": \"{}\",\n", plan.name));
    s.push_str(&format!("  \"plan_hash\": \"{}\",\n", report.plan_hash));
    s.push_str(&format!("  \"git_sha\": \"{}\",\n", report.git_sha));
    s.push_str(&format!("  \"registry_schema_version\": {REGISTRY_SCHEMA_VERSION},\n"));
    let skipped: Vec<String> = report.skipped.iter().map(|c| format!("\"{c}\"")).collect();
    s.push_str(&format!("  \"skipped\": [{}],\n", skipped.join(", ")));
    s.push_str("  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"cell\": \"{}\", \"exec\": \"{}\"",
            c.cell.id(),
            c.cell.exec.name()
        ));
        for (spec, v) in KPIS.iter().zip(&c.kpis) {
            s.push_str(&format!(", \"{}\": {}", spec.name, json_num(*v)));
        }
        s.push_str(if i + 1 < report.cells.len() { "},\n" } else { "}\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One committed baseline row.
#[derive(Clone, Debug, PartialEq)]
pub struct RegistryRow {
    pub git_sha: String,
    pub exec: String,
    pub schema_version: u32,
    pub plan_hash: String,
    pub cell: String,
    /// In [`KPIS`] order.
    pub kpis: Vec<f64>,
}

/// `registry/<plan>.csv` under `dir`.
pub fn registry_path(dir: &Path, plan_name: &str) -> PathBuf {
    dir.join(format!("{plan_name}.csv"))
}

/// The magic line + CSV header every registry file starts with.
pub fn registry_header() -> String {
    let kpi_names: Vec<&str> = KPIS.iter().map(|k| k.name).collect();
    format!(
        "{REGISTRY_MAGIC}\ngit_sha,exec,schema_version,plan_hash,cell,{}\n",
        kpi_names.join(",")
    )
}

fn registry_row_line(report: &PlanReport, cell: &CellResult) -> String {
    let kpis: Vec<String> = cell.kpis.iter().map(|v| format!("{v}")).collect();
    format!(
        "{},{},{REGISTRY_SCHEMA_VERSION},{},{},{}\n",
        report.git_sha,
        cell.cell.exec.name(),
        report.plan_hash,
        cell.cell.id(),
        kpis.join(",")
    )
}

/// Append the report's rows. STRICTLY append-only: an existing file is
/// validated (magic + header) and extended, never truncated or
/// reordered; a fresh file is created with the header. Returns the rows
/// written.
pub fn registry_append(path: &Path, report: &PlanReport) -> Result<usize> {
    let header = registry_header();
    let existing = match std::fs::read_to_string(path) {
        Ok(text) => {
            if !text.starts_with(&header) {
                bail!(
                    "{} does not start with the v{REGISTRY_SCHEMA_VERSION} registry \
                     header — refusing to append (delete or migrate it explicitly)",
                    path.display()
                );
            }
            true
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => false,
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    if !existing {
        f.write_all(header.as_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
    }
    for cell in &report.cells {
        f.write_all(registry_row_line(report, cell).as_bytes())
            .with_context(|| format!("appending to {}", path.display()))?;
    }
    Ok(report.cells.len())
}

/// Load every row (empty when the file does not exist yet — the
/// bootstrap state). Malformed rows are loud errors with line numbers.
pub fn registry_load(path: &Path) -> Result<Vec<RegistryRow>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let header = registry_header();
    if !text.starts_with(&header) {
        bail!(
            "{} does not start with the v{REGISTRY_SCHEMA_VERSION} registry header",
            path.display()
        );
    }
    let mut rows = Vec::new();
    for (i, line) in text.lines().enumerate().skip(2) {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != 5 + KPIS.len() {
            bail!(
                "{}:{}: expected {} fields, found {}",
                path.display(),
                i + 1,
                5 + KPIS.len(),
                fields.len()
            );
        }
        let schema_version: u32 = fields[2]
            .parse()
            .with_context(|| format!("{}:{}: bad schema_version", path.display(), i + 1))?;
        let mut kpis = Vec::with_capacity(KPIS.len());
        for (spec, raw) in KPIS.iter().zip(&fields[5..]) {
            kpis.push(raw.parse::<f64>().with_context(|| {
                format!("{}:{}: bad {} value '{raw}'", path.display(), i + 1, spec.name)
            })?);
        }
        rows.push(RegistryRow {
            git_sha: fields[0].to_string(),
            exec: fields[1].to_string(),
            schema_version,
            plan_hash: fields[3].to_string(),
            cell: fields[4].to_string(),
            kpis,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Checking
// ---------------------------------------------------------------------------

/// What a `--check` comparison found.
#[derive(Clone, Debug, Default)]
pub struct CheckOutcome {
    /// Cells compared against a registry baseline.
    pub compared: usize,
    /// Cells with no matching baseline yet (bootstrap: pass + warn).
    pub bootstrapped: usize,
    pub failures: Vec<String>,
}

impl CheckOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Does `fresh` regress past `base` by more than the band in the KPI's
/// worse direction? Non-finite values always fail (NaN must not slip
/// through a `>` comparison).
fn kpi_failure(spec: &KpiSpec, tol: Tolerance, base: f64, fresh: f64) -> Option<String> {
    if !fresh.is_finite() || !base.is_finite() {
        return Some(format!("{}: non-finite value (base {base}, fresh {fresh})", spec.name));
    }
    let band = tol.abs + tol.rel * base.abs();
    let delta = match spec.worse {
        1 => fresh - base,
        -1 => base - fresh,
        _ => (fresh - base).abs(),
    };
    if delta > band {
        Some(format!(
            "{}: {fresh} vs baseline {base} (drift {delta:.6e} > band {band:.6e})",
            spec.name
        ))
    } else {
        None
    }
}

/// Compare a fresh report against the registry: each cell checks against
/// the LATEST row matching (plan_hash, exec, cell id). Cells without a
/// baseline bootstrap (pass + counted) — a freshly committed plan cannot
/// gate until someone runs `--update` on a real machine and commits the
/// rows.
pub fn check_against_registry(
    plan: &Plan,
    report: &PlanReport,
    rows: &[RegistryRow],
) -> CheckOutcome {
    let mut out = CheckOutcome::default();
    for cell in &report.cells {
        let id = cell.cell.id();
        let exec = cell.cell.exec.name();
        let base = rows.iter().rev().find(|r| {
            r.plan_hash == report.plan_hash
                && r.exec == exec
                && r.cell == id
                && r.schema_version == REGISTRY_SCHEMA_VERSION
        });
        let Some(base) = base else {
            out.bootstrapped += 1;
            continue;
        };
        out.compared += 1;
        for (i, spec) in KPIS.iter().enumerate() {
            let Some(tol) = plan.tolerance_for(spec) else { continue };
            if let Some(msg) = kpi_failure(spec, tol, base.kpis[i], cell.kpis[i]) {
                out.failures.push(format!("{id} exec={exec}: {msg}"));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Provenance
// ---------------------------------------------------------------------------

/// The repository root: the cwd when it looks like the repo, else two
/// levels above this crate's manifest (benches run from crate dirs).
pub fn repo_root() -> PathBuf {
    if let Ok(cwd) = std::env::current_dir() {
        if cwd.join("ablate").is_dir() || cwd.join(".git").exists() {
            return cwd;
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
}

/// The commit to stamp registry rows with: `.git/HEAD` (following one
/// ref indirection, packed or loose), then `GITHUB_SHA`, then
/// `"unknown"` — provenance must never block a run.
pub fn git_sha() -> String {
    fn from_dot_git(root: &Path) -> Option<String> {
        let head = std::fs::read_to_string(root.join(".git/HEAD")).ok()?;
        let head = head.trim();
        let Some(refname) = head.strip_prefix("ref: ") else {
            return (!head.is_empty()).then(|| head.to_string());
        };
        let refname = refname.trim();
        if let Ok(sha) = std::fs::read_to_string(root.join(".git").join(refname)) {
            let sha = sha.trim();
            if !sha.is_empty() {
                return Some(sha.to_string());
            }
        }
        let packed = std::fs::read_to_string(root.join(".git/packed-refs")).ok()?;
        for line in packed.lines() {
            if let Some((sha, name)) = line.split_once(' ') {
                if name.trim() == refname {
                    return Some(sha.trim().to_string());
                }
            }
        }
        None
    }
    from_dot_git(&repo_root())
        .or_else(|| std::env::var("GITHUB_SHA").ok().filter(|s| !s.is_empty()))
        .unwrap_or_else(|| "unknown".to_string())
}

// ---------------------------------------------------------------------------
// Gates: the declarative home of the bench --check thresholds
// ---------------------------------------------------------------------------

/// `[core_ops]` thresholds (`benches/core_ops.rs --check`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoreOpsGates {
    /// Fused forward may be at most `(1 + rel)` x the reference forward
    /// (the old hardcoded 1.10 noise margin).
    pub fused_vs_ref_rel: f64,
    /// Simd forward may be at most `(1 + rel)` x the scalar-fused one.
    pub simd_vs_fused_rel: f64,
    /// Forward parity |fused - reference| ceiling.
    pub parity_abs: f64,
    pub fused_allocs_max: f64,
    pub simd_allocs_max: f64,
}

/// `[serve]` thresholds (`benches/serve_bench.rs --check`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeGates {
    /// Gateway steady-phase p99 budget (ms).
    pub p99_ms: f64,
    pub allocs_max: f64,
}

/// `[train]` thresholds (`benches/train_bench.rs --check`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainGates {
    /// R=1 steady-state allocations-per-step ceiling.
    pub r1_allocs_max: f64,
    /// Multi-replica speedup floor, enforced at `n >= speedup_min_n`.
    pub min_speedup: f64,
    pub speedup_min_n: usize,
}

/// Every bench `--check` threshold, loaded from `ablate/gates.toml` (one
/// reviewable file) with compiled-in identical defaults as the fallback.
#[derive(Clone, Debug, PartialEq)]
pub struct Gates {
    pub core_ops: CoreOpsGates,
    pub serve: ServeGates,
    pub train: TrainGates,
    /// Where these values came from (printed by the benches).
    pub source: String,
}

impl Default for Gates {
    fn default() -> Self {
        Gates {
            core_ops: CoreOpsGates {
                fused_vs_ref_rel: 0.10,
                simd_vs_fused_rel: 0.10,
                parity_abs: 1e-3,
                fused_allocs_max: 0.0,
                simd_allocs_max: 0.0,
            },
            serve: ServeGates { p99_ms: 250.0, allocs_max: 0.0 },
            train: TrainGates { r1_allocs_max: 8.0, min_speedup: 1.5, speedup_min_n: 1024 },
            source: "builtin defaults".to_string(),
        }
    }
}

impl Gates {
    /// Parse a gates document; unknown sections/keys and malformed
    /// values are hard errors (a typo must not silently un-gate CI).
    pub fn parse(text: &str) -> Result<Gates> {
        let doc = parse_toml(text)?;
        let mut g = Gates::default();
        for (section, map) in &doc {
            match section.as_str() {
                "core_ops" => {
                    for (key, v) in map {
                        let dst = match key.as_str() {
                            "fused_vs_ref_rel" => &mut g.core_ops.fused_vs_ref_rel,
                            "simd_vs_fused_rel" => &mut g.core_ops.simd_vs_fused_rel,
                            "parity_abs" => &mut g.core_ops.parity_abs,
                            "fused_allocs_max" => &mut g.core_ops.fused_allocs_max,
                            "simd_allocs_max" => &mut g.core_ops.simd_allocs_max,
                            _ => bail!("unknown [core_ops] gate '{key}'"),
                        };
                        *dst = gate_f64("core_ops", key, v)?;
                    }
                }
                "serve" => {
                    for (key, v) in map {
                        let dst = match key.as_str() {
                            "p99_ms" => &mut g.serve.p99_ms,
                            "allocs_max" => &mut g.serve.allocs_max,
                            _ => bail!("unknown [serve] gate '{key}'"),
                        };
                        *dst = gate_f64("serve", key, v)?;
                    }
                }
                "train" => {
                    for (key, v) in map {
                        match key.as_str() {
                            "r1_allocs_max" => g.train.r1_allocs_max = gate_f64("train", key, v)?,
                            "min_speedup" => g.train.min_speedup = gate_f64("train", key, v)?,
                            "speedup_min_n" => {
                                g.train.speedup_min_n = v
                                    .as_usize()
                                    .context("[train] speedup_min_n must be a non-negative int")?
                            }
                            _ => bail!("unknown [train] gate '{key}'"),
                        }
                    }
                }
                "" => {
                    if let Some(key) = map.keys().next() {
                        bail!("top-level gate key '{key}' — gates live under a section");
                    }
                }
                s => bail!("unknown gates section [{s}] (core_ops/serve/train)"),
            }
        }
        Ok(g)
    }

    pub fn load(path: &Path) -> Result<Gates> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading gates {}", path.display()))?;
        let mut g = Gates::parse(&text).with_context(|| format!("gates {}", path.display()))?;
        g.source = path.display().to_string();
        Ok(g)
    }

    /// The benches' loading order: `SPM_GATES=<path>` (must parse — a
    /// broken override is an error, not a fallback), else the committed
    /// `ablate/gates.toml` at the repo root, else the identical builtin
    /// defaults (a bare crate checkout stays runnable).
    pub fn load_default() -> Result<Gates> {
        if let Ok(path) = std::env::var("SPM_GATES") {
            return Gates::load(Path::new(&path));
        }
        let committed = repo_root().join("ablate").join("gates.toml");
        if committed.exists() {
            return Gates::load(&committed);
        }
        Ok(Gates::default())
    }
}

fn gate_f64(section: &str, key: &str, v: &Value) -> Result<f64> {
    let f = v.as_f64().with_context(|| format!("[{section}] {key} must be a number"))?;
    if !(f.is_finite() && f >= 0.0) {
        bail!("[{section}] {key} must be finite and non-negative");
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "\
[plan]
name = \"tiny\"
seed = 5
steps = 2
rows = 3
n = 8

[axes]
op = [\"spm\", \"dense\"]
variant = [\"rotation\", \"general\"]
schedule = [\"butterfly\"]
stages = [2]
exec = [\"fused\"]
model = [\"mlp\"]

[tolerance.ns_per_row]
rel = 0.5
";

    #[test]
    fn plan_parses_and_round_trips_canonically() {
        let plan = Plan::parse(TINY).unwrap();
        assert_eq!(plan.name, "tiny");
        assert_eq!((plan.steps, plan.rows, plan.n, plan.seed), (2, 3, 8, 5));
        assert_eq!(plan.ops, vec![LinearKind::Spm, LinearKind::Dense]);
        assert_eq!(plan.stages, vec![2]);
        assert_eq!(plan.tolerances["ns_per_row"], Tolerance { abs: 0.0, rel: 0.5 });
        let reparsed = Plan::parse(&plan.canonical()).unwrap();
        assert_eq!(plan, reparsed, "canonical text must parse back to the same plan");
        assert_eq!(plan.hash(), reparsed.hash());
        assert_eq!(plan.hash().len(), 16);
    }

    #[test]
    fn plan_hash_tracks_content_not_formatting() {
        let plan = Plan::parse(TINY).unwrap();
        // reformatting (comments, spacing) does not move the hash
        let reformatted = TINY.replace("steps = 2", "steps =   2   # two");
        assert_eq!(plan.hash(), Plan::parse(&reformatted).unwrap().hash());
        // a real change does
        let changed = TINY.replace("steps = 2", "steps = 3");
        assert_ne!(plan.hash(), Plan::parse(&changed).unwrap().hash());
    }

    #[test]
    fn bad_values_are_rejected_with_line_context() {
        for (bad, needle) in [
            (TINY.replace("op = [\"spm\", \"dense\"]", "op = [\"conv\"]"), "op 'conv'"),
            (TINY.replace("variant = [\"rotation\", \"general\"]", "variant = [\"diag\"]"), "variant 'diag'"),
            (TINY.replace("schedule = [\"butterfly\"]", "schedule = [\"zigzag\"]"), "schedule 'zigzag'"),
            (TINY.replace("exec = [\"fused\"]", "exec = [\"gpu\"]"), "exec 'gpu'"),
            (TINY.replace("model = [\"mlp\"]", "model = [\"cnn\"]"), "model 'cnn'"),
            (TINY.replace("stages = [2]", "stages = [0]"), "stages"),
            (TINY.replace("stages = [2]", "stages = []"), "stages"),
            (TINY.replace("[tolerance.ns_per_row]", "[tolerance.bogus_kpi]"), "bogus_kpi"),
            (TINY.replace("rel = 0.5", "rel = -0.5"), "rel"),
            (TINY.replace("rel = 0.5", "frac = 0.5"), "frac"),
            (TINY.replace("n = 8", "n = 1"), "n"),
            (TINY.replace("seed = 5", "wibble = 5"), "wibble"),
        ] {
            let err = Plan::parse(&bad).unwrap_err().to_string();
            assert!(err.contains(needle), "expected '{needle}' in: {err}");
            assert!(err.contains("line "), "expected line context in: {err}");
        }
        // missing required keys fail loudly (no line to point at)
        let err = Plan::parse("[plan]\nname = \"x\"\n").unwrap_err().to_string();
        assert!(err.contains("steps"), "{err}");
    }

    #[test]
    fn expand_dedupes_dense_and_resolves_env_exec() {
        let plan = Plan::parse(TINY).unwrap();
        let cells = expand(&plan, SpmExec::BatchFused);
        // spm: 2 variants x 1 schedule x 1 stages = 2; dense collapses to 1
        assert_eq!(cells.len(), 3);
        assert_eq!(cells.iter().filter(|c| c.op == LinearKind::Dense).count(), 1);
        // "env" resolves against the ambient exec
        let envp = Plan::parse(&TINY.replace("exec = [\"fused\"]", "exec = [\"env\"]")).unwrap();
        let cells = expand(&envp, SpmExec::RowWise);
        assert!(cells.iter().all(|c| c.exec == SpmExec::RowWise));
        // duplicate axis values fold away
        let dup =
            Plan::parse(&TINY.replace("exec = [\"fused\"]", "exec = [\"fused\", \"fused\"]"))
                .unwrap();
        assert_eq!(expand(&dup, SpmExec::BatchFused).len(), 3);
    }

    #[test]
    fn cell_ids_are_stable_and_csv_safe() {
        let plan = Plan::parse(TINY).unwrap();
        let cells = expand(&plan, SpmExec::BatchFused);
        assert_eq!(cells[0].id(), "model=mlp op=spm variant=rotation schedule=butterfly stages=2");
        assert!(cells.iter().all(|c| !c.id().contains(',')), "ids embed into CSV rows");
        let dense = cells.iter().find(|c| c.op == LinearKind::Dense).unwrap();
        assert_eq!(dense.id(), "model=mlp op=dense");
    }

    /// The zoo kinds collapse the axes they do not consume: one cell per
    /// (model, exec) for lowrank/blockshuffle, one per (model, stages,
    /// exec) for butterfly — and their ids only mention live axes.
    #[test]
    fn zoo_kinds_expand_normalized_and_build() {
        let zoo = TINY.replace(
            "op = [\"spm\", \"dense\"]",
            "op = [\"lowrank\", \"blockshuffle\", \"butterfly\"]",
        );
        let plan = Plan::parse(&zoo).unwrap();
        let cells = expand(&plan, SpmExec::BatchFused);
        // 2 variants would double naive counts; normalization folds them:
        // lowrank 1 + blockshuffle 1 + butterfly 1 (single stages value)
        assert_eq!(cells.len(), 3);
        let ids: Vec<String> = cells.iter().map(Cell::id).collect();
        assert!(ids.contains(&"model=mlp op=lowrank".to_string()), "{ids:?}");
        assert!(ids.contains(&"model=mlp op=blockshuffle".to_string()), "{ids:?}");
        assert!(ids.contains(&"model=mlp op=butterfly stages=2".to_string()), "{ids:?}");
        // every zoo cell lowers into a buildable model config
        for cell in &cells {
            let cfg = cell.to_model_cfg(&plan);
            let model = build_model(&cfg);
            assert!(model.param_count() > 0, "{}", cell.id());
        }
    }

    #[test]
    fn tolerance_defaults_by_kpi_class() {
        let plan = Plan::parse(TINY).unwrap();
        let loss = &KPIS[kpi_index("loss").unwrap()];
        assert_eq!(plan.tolerance_for(loss), Some(Tolerance { abs: 0.0, rel: 0.0 }));
        let ns = &KPIS[kpi_index("ns_per_row").unwrap()];
        assert_eq!(plan.tolerance_for(ns), Some(Tolerance { abs: 0.0, rel: 0.5 }));
        let rps = &KPIS[kpi_index("rows_per_sec").unwrap()];
        assert_eq!(plan.tolerance_for(rps), None, "undeclared measured KPIs are ungated");
    }

    #[test]
    fn kpi_failure_is_one_sided_and_nan_safe() {
        let loss = &KPIS[kpi_index("loss").unwrap()];
        let zero = Tolerance { abs: 0.0, rel: 0.0 };
        assert!(kpi_failure(loss, zero, 1.0, 1.0).is_none());
        assert!(kpi_failure(loss, zero, 1.0, 1.0000001).is_some(), "higher loss fails");
        assert!(kpi_failure(loss, zero, 1.0, 0.5).is_none(), "improvement passes");
        let acc = &KPIS[kpi_index("acc").unwrap()];
        assert!(kpi_failure(acc, zero, 0.9, 0.8).is_some(), "lower acc fails");
        assert!(kpi_failure(acc, zero, 0.8, 0.9).is_none());
        let params = &KPIS[kpi_index("param_count").unwrap()];
        assert!(kpi_failure(params, zero, 100.0, 101.0).is_some(), "identity drift fails");
        assert!(kpi_failure(params, zero, 100.0, 99.0).is_some(), "either direction");
        let band = Tolerance { abs: 0.0, rel: 0.10 };
        assert!(kpi_failure(loss, band, 1.0, 1.09).is_none(), "inside the band");
        assert!(kpi_failure(loss, band, 1.0, 1.11).is_some(), "outside the band");
        assert!(kpi_failure(loss, zero, 1.0, f64::NAN).is_some(), "NaN must not pass");
        assert!(kpi_failure(loss, zero, f64::NAN, 1.0).is_some());
    }

    #[test]
    fn registry_lines_round_trip_exactly() {
        let report = PlanReport {
            plan_name: "tiny".into(),
            plan_hash: "0123456789abcdef".into(),
            git_sha: "deadbeef".into(),
            cells: vec![CellResult {
                cell: Cell {
                    model: ModelKind::Mlp,
                    op: LinearKind::Spm,
                    variant: Variant::General,
                    schedule: Schedule::Butterfly,
                    stages: Some(3),
                    exec: SpmExec::BatchFused,
                },
                kpis: [0.6931471805599453, 0.5, 123.0, 456.0, 0.0, 1234.5678, 810000.25],
            }],
            skipped: Vec::new(),
        };
        let line = registry_row_line(&report, &report.cells[0]);
        let text = format!("{}{line}", registry_header());
        // parse back through the loader's field logic via a temp-free path:
        // write/load goes through files in tests/ablate.rs; here check the
        // f64 Display round-trip that exactness rests on
        let fields: Vec<&str> = line.trim().split(',').collect();
        assert_eq!(fields.len(), 5 + KPIS.len());
        for (raw, v) in fields[5..].iter().zip(&report.cells[0].kpis) {
            assert_eq!(raw.parse::<f64>().unwrap().to_bits(), v.to_bits());
        }
        assert!(text.starts_with(REGISTRY_MAGIC));
    }

    #[test]
    fn gates_parse_strictly_and_default_sanely() {
        let g = Gates::default();
        assert_eq!(g.core_ops.fused_vs_ref_rel, 0.10);
        assert_eq!(g.serve.p99_ms, 250.0);
        assert_eq!(g.train.speedup_min_n, 1024);
        let parsed =
            Gates::parse("[serve]\np99_ms = 300\n[train]\nmin_speedup = 1.2\n").unwrap();
        assert_eq!(parsed.serve.p99_ms, 300.0);
        assert_eq!(parsed.train.min_speedup, 1.2);
        assert_eq!(parsed.core_ops, g.core_ops, "untouched sections keep defaults");
        assert!(Gates::parse("[serve]\np99 = 300\n").is_err(), "unknown key");
        assert!(Gates::parse("[webserve]\np99_ms = 300\n").is_err(), "unknown section");
        assert!(Gates::parse("[serve]\np99_ms = -1\n").is_err(), "negative gate");
    }

    #[test]
    fn fnv_is_the_reference_function() {
        // FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn line_context_helpers_find_keys() {
        assert_eq!(line_of(TINY, "plan", "steps"), 4);
        assert_eq!(line_of(TINY, "axes", "model"), 14);
        assert_eq!(line_of_section(TINY, "tolerance.ns_per_row"), 16);
        assert_eq!(line_of(TINY, "plan", "nope"), 0);
    }
}
