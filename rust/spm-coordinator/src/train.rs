//! The data-parallel training engine (DESIGN.md §14): the training-side
//! sibling of `serve::ServeEngine`. An epoch's minibatch stream is split
//! into groups of `accum` microbatches; each group's microbatches fan
//! out round-robin across R replica models on scoped worker threads
//! (forward + backward per replica via `Model::accumulate_step`), their
//! gradients combine in a **deterministic** chunked all-reduce, and ONE
//! optimizer step fires on the primary, whose updated parameters
//! broadcast back to every replica through `visit_params_mut`.
//!
//! ## Deterministic reduction contract
//!
//! The all-reduce never sums "replica buffers in whatever order workers
//! finish". Every microbatch's gradient is snapshotted separately and
//! the reduction walks the parameter space in fixed chunks, summing the
//! snapshots in **global microbatch order** (then scaling by
//! `1/group_len`) — element `i` always sees
//! `((g_0[i] + g_1[i]) + g_2[i]) + ...` no matter how many replicas
//! computed them, how the chunks were threaded, or which worker
//! finished first. No atomics anywhere. Because each microbatch is
//! computed whole by one replica under a pinned per-replica thread
//! budget, the resulting parameter trajectory depends only on
//! `(stream, accum, threads_per_replica)` — NOT on the replica count:
//! R=1 and R=4 produce bit-identical post-step parameters. (Auto
//! `threads_per_replica = 0` divides the global budget by R, which is
//! still deterministic per configuration but makes different replica
//! counts thread — and therefore round — their per-microbatch partials
//! differently; pin it explicitly when comparing across R.)
//!
//! ## Thread budget
//!
//! Each replica worker runs its kernels under
//! `parallel::with_thread_budget(threads_per_replica, ..)`, so R
//! replicas split one core budget instead of each claiming
//! `available_parallelism()` (R-fold oversubscription — the bug this
//! engine and `ServeEngine` both fix).

use std::time::Instant;

use spm_core::models::api::{build_model, Model, ModelCfg, Target};
use spm_core::parallel;
use spm_core::tensor::Mat;

/// Parameter-space chunk (f32 elements) the all-reduce walks. Chunking
/// is a cache/parallelism shape only: per-element summation order is
/// fixed by the snapshot order, so any chunk size or thread count
/// produces identical sums.
const REDUCE_CHUNK: usize = 8192;

/// Owned training target for one microbatch (the storage behind the
/// borrowed `models::api::Target` the trait consumes).
pub enum TrainTarget {
    Labels(Vec<u32>),
    Values(Mat),
}

impl TrainTarget {
    /// Borrow as the `Model`-facing target enum.
    pub fn as_target(&self) -> Target<'_> {
        match self {
            TrainTarget::Labels(y) => Target::Labels(y),
            TrainTarget::Values(m) => Target::Values(m),
        }
    }
}

/// One microbatch: feature rows plus their target.
pub struct TrainBatch {
    pub x: Mat,
    pub target: TrainTarget,
}

impl TrainBatch {
    pub fn labels(x: Mat, y: Vec<u32>) -> TrainBatch {
        assert_eq!(x.rows, y.len(), "one label per row");
        TrainBatch { x, target: TrainTarget::Labels(y) }
    }

    pub fn values(x: Mat, t: Mat) -> TrainBatch {
        assert_eq!(x.rows, t.rows, "one target row per input row");
        TrainBatch { x, target: TrainTarget::Values(t) }
    }

    pub fn rows(&self) -> usize {
        self.x.rows
    }
}

/// What one `train_epoch` did.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Optimizer steps taken (groups of `accum` microbatches).
    pub steps: usize,
    pub microbatches: usize,
    pub rows: usize,
    /// Mean loss over the epoch's microbatches.
    pub mean_loss: f64,
    /// Mean task metric (accuracy where defined) over the microbatches.
    pub mean_metric: f64,
    pub wall_secs: f64,
    pub rows_per_sec: f64,
    /// Microbatches each replica computed, in replica order.
    pub replica_microbatches: Vec<usize>,
}

impl std::fmt::Display for TrainReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "steps         : {} ({} microbatches)", self.steps, self.microbatches)?;
        if self.replica_microbatches.len() > 1 {
            writeln!(f, "replicas      : {:?} microbatches", self.replica_microbatches)?;
        }
        writeln!(f, "mean loss     : {:.4}", self.mean_loss)?;
        writeln!(f, "mean metric   : {:.4}", self.mean_metric)?;
        write!(f, "throughput    : {:.0} rows/s", self.rows_per_sec)
    }
}

/// The one microbatch-assignment policy: microbatch `m` of a group runs
/// on replica `assigned_replica(m, r)`. `step` computes with it and
/// `train_epoch` accounts with it — change it here and both stay
/// truthful.
fn assigned_replica(m: usize, r: usize) -> usize {
    m % r
}

fn load_params(model: &mut dyn Model, flat: &[f32]) {
    let mut off = 0usize;
    model.visit_params_mut(&mut |_n, p| {
        p.copy_from_slice(&flat[off..off + p.len()]);
        off += p.len();
    });
    assert_eq!(off, flat.len(), "param broadcast must cover every buffer");
}

/// Snapshot the model's flat gradient view into a caller-owned buffer
/// (cleared + refilled, so reused slots never allocate in steady state).
fn flat_grads_into(model: &dyn Model, out: &mut Vec<f32>) {
    out.clear();
    model.visit_grads(&mut |_n, g| out.extend_from_slice(g));
}

/// Accumulate the model's flat gradient view into `acc` element-wise —
/// the single-replica reduce: when one replica owns every microbatch the
/// per-microbatch snapshots collapse to in-place accumulation in
/// microbatch order, which sums element `i` as `(g_0[i] + g_1[i]) + ...`
/// exactly like the chunked snapshot reduce does.
fn add_grads(model: &dyn Model, acc: &mut [f32]) {
    let mut off = 0usize;
    model.visit_grads(&mut |_n, g| {
        for (a, v) in acc[off..off + g.len()].iter_mut().zip(g) {
            *a += v;
        }
        off += g.len();
    });
    assert_eq!(off, acc.len(), "gradient reduce must cover every buffer");
}

fn load_grads(model: &mut dyn Model, flat: &[f32]) {
    let mut off = 0usize;
    model.visit_grads_mut(&mut |_n, g| {
        g.copy_from_slice(&flat[off..off + g.len()]);
        off += g.len();
    });
    assert_eq!(off, flat.len(), "gradient write-back must cover every buffer");
}

/// Reusable step workspace (DESIGN.md §15): the all-reduce accumulator,
/// per-microbatch gradient-snapshot slots (multi-replica path, in
/// microbatch order), per-microbatch metrics and the parameter-broadcast
/// buffer all live across steps, so the steady-state single-replica step
/// allocates nothing here.
#[derive(Default)]
struct StepWorkspace {
    acc: Vec<f32>,
    snaps: Vec<Vec<f32>>,
    metrics: Vec<(f32, f32)>,
    bcast: Vec<f32>,
}

/// Builder + driver for data-parallel training: replica models, the
/// group/thread policy, then [`TrainEngine::train_epoch`] (or
/// [`TrainEngine::step`] per group) over a microbatch stream.
pub struct TrainEngine {
    /// `replicas[0]` is the primary: it owns the optimizer trajectory
    /// and is the model `into_model` hands back.
    replicas: Vec<Box<dyn Model>>,
    threads_per_replica: usize,
    accum: usize,
    synced: bool,
    ws: StepWorkspace,
}

impl TrainEngine {
    /// Single-replica engine around `primary` (add shards with
    /// [`TrainEngine::with_replica`]).
    pub fn new(primary: Box<dyn Model>) -> TrainEngine {
        TrainEngine {
            replicas: vec![primary],
            threads_per_replica: 0,
            accum: 0,
            synced: false,
            ws: StepWorkspace::default(),
        }
    }

    /// Build `replicas` identical models from one factory config — the
    /// cheapest checkpoint-sync (same config, same seeded init; the
    /// engine re-broadcasts the primary's parameters before the first
    /// step regardless, so a warm-started primary also works).
    pub fn from_cfg(cfg: &ModelCfg, replicas: usize) -> TrainEngine {
        assert!(replicas >= 1, "need at least one replica");
        let mut engine = TrainEngine::new(build_model(cfg));
        for _ in 1..replicas {
            engine = engine.with_replica(build_model(cfg));
        }
        engine
    }

    /// Add a replica model (its own worker thread during a step). Must
    /// match the primary's architecture; its parameters are overwritten
    /// by the primary's before the first step.
    pub fn with_replica(mut self, model: Box<dyn Model>) -> TrainEngine {
        let p = &self.replicas[0];
        assert_eq!(p.kind(), model.kind(), "replica architecture");
        assert_eq!(p.d_in(), model.d_in(), "replica d_in");
        assert_eq!(p.d_out(), model.d_out(), "replica d_out");
        assert_eq!(p.param_count(), model.param_count(), "replica param count");
        self.replicas.push(model);
        self.synced = false;
        self
    }

    /// Worker threads EACH replica's kernels may use. 0 (default) splits
    /// the global `parallel::num_threads()` budget evenly:
    /// `floor(budget / replicas)`, min 1. Pin this explicitly when the
    /// parameter trajectory must be comparable across replica counts.
    pub fn with_threads_per_replica(mut self, threads: usize) -> TrainEngine {
        self.threads_per_replica = threads;
        self
    }

    /// Microbatches reduced into ONE optimizer step. 0 (default) means
    /// one per replica. Pin this explicitly (together with
    /// `threads_per_replica`) to make the trajectory independent of the
    /// replica count.
    pub fn with_accum(mut self, accum: usize) -> TrainEngine {
        self.accum = accum;
        self
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Microbatches per optimizer step after defaulting.
    pub fn accum_per_step(&self) -> usize {
        if self.accum == 0 {
            self.replicas.len()
        } else {
            self.accum
        }
    }

    /// The per-replica thread budget after defaulting.
    pub fn threads_per_replica(&self) -> usize {
        if self.threads_per_replica > 0 {
            self.threads_per_replica
        } else {
            (parallel::num_threads() / self.replicas.len()).max(1)
        }
    }

    /// The primary model (evaluation, checkpointing).
    pub fn model(&self) -> &dyn Model {
        self.replicas[0].as_ref()
    }

    /// Mutable access to the primary (warm-starting, param edits). The
    /// caller may change parameters, so the next step re-broadcasts the
    /// primary to every replica before computing anything.
    pub fn model_mut(&mut self) -> &mut dyn Model {
        self.synced = false;
        self.replicas[0].as_mut()
    }

    /// Hand the trained primary back.
    pub fn into_model(mut self) -> Box<dyn Model> {
        self.replicas.swap_remove(0)
    }

    /// Broadcast the primary's parameters to every other replica through
    /// the persistent broadcast buffer.
    fn broadcast_params(&mut self) {
        if self.replicas.len() > 1 {
            let bcast = &mut self.ws.bcast;
            bcast.clear();
            self.replicas[0].visit_params(&mut |_n, p| bcast.extend_from_slice(p));
            for rep in self.replicas[1..].iter_mut() {
                load_params(rep.as_mut(), bcast);
            }
        }
        self.synced = true;
    }

    /// ONE optimizer step over a group of microbatches: fan the group
    /// out round-robin (microbatch m -> replica `m % R`), all-reduce the
    /// per-microbatch gradient snapshots in global microbatch order,
    /// apply on the primary, broadcast. Returns the group's mean
    /// `(loss, metric)`.
    pub fn step(&mut self, group: &[TrainBatch]) -> (f32, f32) {
        assert!(!group.is_empty(), "a train step needs at least one microbatch");
        if !self.synced {
            self.broadcast_params();
        }
        let r = self.replicas.len();
        let tpr = self.threads_per_replica();

        // fast path for the default shape (1 replica, 1 microbatch per
        // step): the reduce would be the identity, so skip the snapshot
        // + zeroed accumulator + write-back and train like the pre-engine
        // train_step. Parameter-trajectory-identical to the general path
        // (the only bit that can differ is the sign of zero gradients,
        // which every optimizer kernel maps to the same parameters).
        if r == 1 && group.len() == 1 {
            let mb = &group[0];
            let model = self.replicas[0].as_mut();
            return parallel::with_thread_budget(tpr, || {
                model.zero_grads();
                let lm = model.accumulate_step(&mb.x, &mb.target.as_target());
                model.apply_step();
                lm
            });
        }

        let total = self.replicas[0].param_count();
        let inv = 1.0 / group.len() as f32;
        if self.ws.metrics.len() < group.len() {
            self.ws.metrics.resize(group.len(), (0.0, 0.0));
        }

        if r == 1 {
            // a single replica owns EVERY microbatch, so the snapshot
            // slots and the chunked reduce collapse to in-place
            // accumulation in microbatch order — bit-identical to the
            // general reduce (element `i` still sums
            // `(g_0[i] + g_1[i]) + ...` from a zeroed accumulator) with
            // zero steady-state allocations.
            let ws = &mut self.ws;
            ws.acc.clear();
            ws.acc.resize(total, 0.0);
            let (acc, metrics) = (&mut ws.acc, &mut ws.metrics[..group.len()]);
            let model = self.replicas[0].as_mut();
            parallel::with_thread_budget(tpr, || {
                for (mb, met) in group.iter().zip(metrics.iter_mut()) {
                    model.zero_grads();
                    *met = model.accumulate_step(&mb.x, &mb.target.as_target());
                    add_grads(&*model, acc);
                }
            });
            for a in self.ws.acc.iter_mut() {
                *a *= inv;
            }
        } else {
            // persistent per-microbatch snapshot slots, dealt round-robin
            // to the replica workers (microbatch m -> replica m % R); the
            // slots land pre-sorted in microbatch order.
            if self.ws.snaps.len() < group.len() {
                self.ws.snaps.resize_with(group.len(), Vec::new);
            }
            let snaps = &mut self.ws.snaps[..group.len()];
            let metrics = &mut self.ws.metrics[..group.len()];
            std::thread::scope(|s| {
                let mut slots: Vec<Vec<(&TrainBatch, &mut Vec<f32>, &mut (f32, f32))>> =
                    (0..r).map(|_| Vec::new()).collect();
                for (((m, mb), snap), met) in
                    group.iter().enumerate().zip(snaps.iter_mut()).zip(metrics.iter_mut())
                {
                    slots[assigned_replica(m, r)].push((mb, snap, met));
                }
                let mut handles = Vec::with_capacity(r);
                for (model, assigned) in self.replicas.iter_mut().zip(slots) {
                    handles.push(s.spawn(move || {
                        parallel::with_thread_budget(tpr, || {
                            for (mb, snap, met) in assigned {
                                model.zero_grads();
                                *met = model.accumulate_step(&mb.x, &mb.target.as_target());
                                flat_grads_into(&**model, snap);
                            }
                        })
                    }));
                }
                for h in handles {
                    // propagate a worker panic verbatim instead of minting
                    // a second panic site at the join (DESIGN.md §16)
                    if let Err(p) = h.join() {
                        std::panic::resume_unwind(p);
                    }
                }
            });

            // deterministic chunked all-reduce: per element, snapshots
            // sum in microbatch order; chunks only shape cache traffic /
            // threading
            let ws = &mut self.ws;
            ws.acc.clear();
            ws.acc.resize(total, 0.0);
            let snaps = &ws.snaps[..group.len()];
            let chunk_len = REDUCE_CHUNK.min(total.max(1));
            parallel::for_each_chunk(&mut ws.acc, chunk_len, |first, chunk| {
                let off = first * chunk_len;
                for snap in snaps {
                    for (a, v) in chunk.iter_mut().zip(&snap[off..off + chunk.len()]) {
                        *a += v;
                    }
                }
                for a in chunk.iter_mut() {
                    *a *= inv;
                }
            });
        }

        let (replicas, ws) = (&mut self.replicas, &self.ws);
        let primary = replicas[0].as_mut();
        load_grads(primary, &ws.acc);
        primary.apply_step();
        self.broadcast_params();

        let metrics = &self.ws.metrics[..group.len()];
        let loss_sum: f64 = metrics.iter().map(|&(l, _)| l as f64).sum();
        let metric_sum: f64 = metrics.iter().map(|&(_, a)| a as f64).sum();
        let k = group.len() as f64;
        ((loss_sum / k) as f32, (metric_sum / k) as f32)
    }

    /// Drive one epoch: `batches` in groups of [`TrainEngine::accum_per_step`]
    /// microbatches, one optimizer step per group (a ragged tail group
    /// steps at its true size).
    pub fn train_epoch(&mut self, batches: &[TrainBatch]) -> TrainReport {
        let accum = self.accum_per_step();
        let r = self.replicas.len();
        let t0 = Instant::now();
        let mut report = TrainReport { replica_microbatches: vec![0; r], ..Default::default() };
        let mut loss_sum = 0.0f64;
        let mut metric_sum = 0.0f64;
        for group in batches.chunks(accum) {
            let (l, a) = self.step(group);
            report.steps += 1;
            report.microbatches += group.len();
            report.rows += group.iter().map(TrainBatch::rows).sum::<usize>();
            loss_sum += l as f64 * group.len() as f64;
            metric_sum += a as f64 * group.len() as f64;
            for m in 0..group.len() {
                report.replica_microbatches[assigned_replica(m, r)] += 1;
            }
        }
        report.wall_secs = t0.elapsed().as_secs_f64();
        let k = report.microbatches.max(1) as f64;
        report.mean_loss = loss_sum / k;
        report.mean_metric = metric_sum / k;
        report.rows_per_sec = report.rows as f64 / report.wall_secs.max(1e-9);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    use spm_core::models::api::ModelKind;
    use spm_core::ops::LinearOp;

    /// Minimal deterministic `Model`: params/grads are one 4-wide
    /// buffer; `accumulate_step` writes `scale * first-row` into the
    /// grads and records the thread budget it observed; `apply_step`
    /// does `p -= g`. Lets the engine tests pin down assignment,
    /// reduction order, and the per-replica thread split without real
    /// kernels in the way.
    struct MockModel {
        params: Vec<f32>,
        grads: Vec<f32>,
        scale: f32,
        steps_applied: usize,
        seen_budgets: Arc<Mutex<Vec<usize>>>,
        microbatches_run: Arc<AtomicUsize>,
    }

    impl MockModel {
        fn new(scale: f32) -> MockModel {
            MockModel {
                params: vec![0.0; 4],
                grads: vec![0.0; 4],
                scale,
                steps_applied: 0,
                seen_budgets: Arc::new(Mutex::new(Vec::new())),
                microbatches_run: Arc::new(AtomicUsize::new(0)),
            }
        }

        fn boxed(scale: f32) -> Box<MockModel> {
            Box::new(MockModel::new(scale))
        }
    }

    impl Model for MockModel {
        fn kind(&self) -> ModelKind {
            ModelKind::Mlp
        }

        fn d_in(&self) -> usize {
            4
        }

        fn d_out(&self) -> usize {
            4
        }

        fn param_count(&self) -> usize {
            self.params.len()
        }

        fn forward(&self, x: &Mat) -> Mat {
            x.clone()
        }

        fn accumulate_step(&mut self, x: &Mat, _target: &Target) -> (f32, f32) {
            self.seen_budgets.lock().unwrap().push(parallel::num_threads());
            self.microbatches_run.fetch_add(1, Ordering::SeqCst);
            for (g, v) in self.grads.iter_mut().zip(x.row(0)) {
                *g += self.scale * v;
            }
            (x.row(0)[0], 0.0)
        }

        fn apply_step(&mut self) {
            for (p, g) in self.params.iter_mut().zip(&self.grads) {
                *p -= *g;
            }
            self.grads.fill(0.0);
            self.steps_applied += 1;
        }

        fn zero_grads(&mut self) {
            self.grads.fill(0.0);
        }

        fn evaluate(&self, _x: &Mat, _target: &Target) -> (f32, f32) {
            (0.0, 0.0)
        }

        fn set_exec(&mut self, _exec: spm_core::ops::SpmExec) {}

        fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32])) {
            f("p", &self.params);
        }

        fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
            f("p", &mut self.params);
        }

        fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32])) {
            f("p", &self.grads);
        }

        fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
            f("p", &mut self.grads);
        }

        fn visit_ops(&self, _f: &mut dyn FnMut(&LinearOp)) {}
    }

    fn mb(v: f32) -> TrainBatch {
        TrainBatch::labels(Mat::from_vec(1, 4, vec![v, 0.0, 0.0, 0.0]), vec![0])
    }

    #[test]
    fn step_reduces_microbatches_in_order_and_applies_once() {
        // grads per microbatch m are (m+1) * e0; mean over the group
        // must land on the primary regardless of which replica ran what
        let primary = MockModel::boxed(1.0);
        let steps_seen = primary.microbatches_run.clone();
        let mut engine = TrainEngine::new(primary)
            .with_replica(MockModel::boxed(1.0))
            .with_accum(4)
            .with_threads_per_replica(1);
        let group: Vec<TrainBatch> = (0..4).map(|m| mb((m + 1) as f32)).collect();
        let (loss, _metric) = engine.step(&group);
        // losses are the first features: mean of 1..=4
        assert_eq!(loss, 2.5);
        // primary param[0] = -(1+2+3+4)/4
        let mut p = Vec::new();
        engine.model().visit_params(&mut |_n, b| p.extend_from_slice(b));
        assert_eq!(p[0], -2.5);
        assert_eq!(steps_seen.load(Ordering::SeqCst), 2, "round-robin: primary ran 2 of 4");
    }

    #[test]
    fn single_replica_single_microbatch_fast_path_applies_directly() {
        // the default-config hot path (r=1, group=1) skips the snapshot
        // + reduce; the optimizer must still consume the full gradient
        let mut engine = TrainEngine::new(MockModel::boxed(1.0));
        let (loss, _metric) = engine.step(&[mb(2.0)]);
        assert_eq!(loss, 2.0);
        let mut p = Vec::new();
        engine.model().visit_params(&mut |_n, b| p.extend_from_slice(b));
        assert_eq!(p[0], -2.0);
    }

    #[test]
    fn model_mut_forces_a_resync_before_the_next_step() {
        // editing the primary through model_mut must re-broadcast: the
        // replica's params must match the edited primary after the step
        let mut engine = TrainEngine::new(MockModel::boxed(1.0))
            .with_replica(MockModel::boxed(1.0))
            .with_threads_per_replica(1);
        engine.step(&[mb(1.0), mb(2.0)]);
        engine.model_mut().visit_params_mut(&mut |_n, p| p.fill(7.0));
        engine.step(&[mb(0.0), mb(0.0)]);
        let mut p0 = Vec::new();
        engine.replicas[0].visit_params(&mut |_n, b| p0.extend_from_slice(b));
        let mut p1 = Vec::new();
        engine.replicas[1].visit_params(&mut |_n, b| p1.extend_from_slice(b));
        assert_eq!(p0, vec![7.0; 4], "zero-feature microbatches leave params at the edit");
        assert_eq!(p0, p1, "replica must adopt the edited primary");
    }

    #[test]
    fn replicas_see_the_partitioned_thread_budget() {
        // satellite regression: each replica's kernels must observe the
        // per-replica budget, not the whole machine
        let primary = MockModel::boxed(1.0);
        let replica = MockModel::boxed(1.0);
        let budgets = [primary.seen_budgets.clone(), replica.seen_budgets.clone()];
        let mut engine = TrainEngine::new(primary)
            .with_replica(replica)
            .with_threads_per_replica(3)
            .with_accum(4);
        let group: Vec<TrainBatch> = (0..4).map(|m| mb(m as f32)).collect();
        engine.step(&group);
        for (i, b) in budgets.iter().enumerate() {
            let seen = b.lock().unwrap();
            assert_eq!(seen.len(), 2, "replica {i} ran 2 microbatches");
            assert!(seen.iter().all(|&t| t == 3), "replica {i} saw budgets {seen:?}");
        }
    }

    #[test]
    fn trajectory_is_independent_of_replica_count() {
        // same stream, same accum, pinned threads: R=1 and R=3 must
        // produce identical params (the mock's grads are exact, so this
        // checks the engine's ordering, not float luck)
        let batches: Vec<TrainBatch> = (0..9).map(|m| mb((m as f32) * 0.25 + 1.0)).collect();
        let run = |replicas: usize| -> Vec<f32> {
            let mut engine = TrainEngine::new(MockModel::boxed(1.0));
            for _ in 1..replicas {
                engine = engine.with_replica(MockModel::boxed(1.0));
            }
            let mut engine = engine.with_accum(3).with_threads_per_replica(1);
            engine.train_epoch(&batches);
            let mut p = Vec::new();
            engine.model().visit_params(&mut |_n, b| p.extend_from_slice(b));
            p
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn unsynced_replicas_adopt_the_primary_before_the_first_step() {
        // replica starts with different params; first step must
        // broadcast the primary's before computing anything that leaks
        // into the trajectory (the mock's grads ignore params, so check
        // the replica's params directly after one step)
        let primary = MockModel::boxed(1.0);
        let mut replica = MockModel::new(1.0);
        replica.params = vec![9.0; 4];
        let mut engine = TrainEngine::new(primary).with_replica(Box::new(replica));
        engine.step(&[mb(1.0), mb(2.0)]);
        // after the step every replica holds the primary's params
        let mut p0 = Vec::new();
        engine.replicas[0].visit_params(&mut |_n, b| p0.extend_from_slice(b));
        let mut p1 = Vec::new();
        engine.replicas[1].visit_params(&mut |_n, b| p1.extend_from_slice(b));
        assert_eq!(p0, p1);
        assert_ne!(p1, vec![9.0; 4]);
    }

    #[test]
    fn train_epoch_groups_and_accounts_microbatches() {
        let mut engine = TrainEngine::new(MockModel::boxed(1.0))
            .with_replica(MockModel::boxed(1.0))
            .with_accum(3)
            .with_threads_per_replica(1);
        let batches: Vec<TrainBatch> = (0..7).map(|m| mb(m as f32)).collect();
        let report = engine.train_epoch(&batches);
        assert_eq!(report.steps, 3, "7 microbatches in groups of 3 = 3 steps");
        assert_eq!(report.microbatches, 7);
        assert_eq!(report.rows, 7);
        assert_eq!(report.replica_microbatches.iter().sum::<usize>(), 7);
        assert!(report.replica_microbatches.iter().all(|&m| m > 0));
        assert!(report.wall_secs >= 0.0);
    }

    #[test]
    fn accum_defaults_to_replica_count() {
        let engine = TrainEngine::new(MockModel::boxed(1.0))
            .with_replica(MockModel::boxed(1.0))
            .with_replica(MockModel::boxed(1.0));
        assert_eq!(engine.accum_per_step(), 3);
        assert_eq!(engine.replica_count(), 3);
        let pinned = TrainEngine::new(MockModel::boxed(1.0)).with_accum(5);
        assert_eq!(pinned.accum_per_step(), 5);
    }

    #[test]
    #[should_panic(expected = "replica param count")]
    fn mismatched_replica_is_rejected() {
        let mut other = MockModel::new(1.0);
        other.params = vec![0.0; 8];
        let _ = TrainEngine::new(MockModel::boxed(1.0)).with_replica(Box::new(other));
    }
}
