#!/usr/bin/env python3
"""Toolchain-less mirror of `spm-lint` (rust/spm-lint, DESIGN.md §18).

The canonical implementation of the repo-invariant rule set R1-R6 is the
dependency-free Rust crate `rust/spm-lint`; this file re-implements the
same lexer + rules in stdlib Python so `./ci.sh --lint` still runs in
containers without a Rust toolchain (the environment every PR note in
CHANGES.md complains about). Rule IDs, messages, file discovery,
suppression grammar, and the baseline format are kept in lockstep with
the crate — `rust/spm-lint/tests/selfcheck.rs` and this script must
agree that the committed tree is clean. When editing a rule, edit BOTH.

Usage: python3 tools/spm_lint.py [--root DIR] [--json PATH]
Exit status: 0 = clean, 1 = findings, 2 = usage/IO error.
"""

import json
import os
import re
import sys

# --------------------------------------------------------------------------
# Lexer: comment/string/char-literal aware masking (mirror of lexer.rs)
# --------------------------------------------------------------------------


class Lexed:
    """`mask` is the source with comment bodies and string/char literal
    contents blanked to spaces (newlines kept, so byte offsets and line
    numbers survive); `comments` / `strings` record what was blanked."""

    def __init__(self, mask, comments, strings):
        self.mask = mask
        self.comments = comments  # list of (line, text) — text w/o // or /* */
        self.strings = strings  # list of (line, contents)


def lex(src):
    n = len(src)
    out = list(src)
    comments = []
    strings = []
    i = 0
    line = 1

    def blank(a, b):
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = src.find("\n", i)
            if j == -1:
                j = n
            comments.append((line, src[i + 2 : j]))
            blank(i, j)
            i = j
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            start, start_line = i, line
            depth = 1
            i += 2
            while i < n and depth > 0:
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            comments.append((start_line, src[start + 2 : max(start + 2, i - 2)]))
            blank(start, i)
            continue
        if c == "r" or (c == "b" and i + 1 < n and src[i + 1] == "r"):
            # raw (byte) string r"..." / r#"..."# / br#"..."#
            j = i + (1 if c == "r" else 2)
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            if j < n and src[j] == '"' and (hashes > 0 or src[i : i + 2] in ('r"', "br") ):
                close = '"' + "#" * hashes
                k = src.find(close, j + 1)
                if k == -1:
                    k = n
                start_line = line
                line += src.count("\n", i, k)
                strings.append((start_line, src[j + 1 : k]))
                blank(j + 1, k)
                i = k + len(close)
                continue
        if c == "b" and i + 1 < n and src[i + 1] == '"':
            i += 1
            c = '"'
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    break
                j += 1
            start_line = line
            line += src.count("\n", i, j)
            strings.append((start_line, src[i + 1 : min(j, n)]))
            blank(i + 1, min(j, n))
            i = min(j, n) + 1
            continue
        if c == "'":
            # char literal vs lifetime: 'x' or '\..' is a literal,
            # 'ident (no closing quote right after) is a lifetime
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                blank(i + 1, j)
                i = j + 1
                continue
            if i + 2 < n and src[i + 2] == "'":
                blank(i + 1, i + 2)
                i = i + 3
                continue
            i += 1
            continue
        i += 1
    return Lexed("".join(out), comments, strings)


# --------------------------------------------------------------------------
# File model + discovery (mirror of tree.rs)
# --------------------------------------------------------------------------

SKIP_DIRS = {".git", "target", "python", "artifacts", "fixtures", "node_modules"}


class SourceFile:
    def __init__(self, path, text):
        self.path = path  # root-relative, forward slashes
        self.text = text
        self.lex = lex(text)
        self.lines = text.split("\n")


class Tree:
    """Everything a rule may consult: the .rs files plus the repo-level
    artifacts R5 cross-checks (DESIGN.md, registry/*.csv)."""

    def __init__(self, root):
        self.root = root
        self.files = []
        self.design = None  # DESIGN.md text or None
        self.registry = []  # list of (rel path, first line)
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS)
            for f in sorted(filenames):
                if f.endswith(".rs"):
                    p = os.path.join(dirpath, f)
                    rel = os.path.relpath(p, root).replace(os.sep, "/")
                    with open(p, encoding="utf-8") as fh:
                        self.files.append(SourceFile(rel, fh.read()))
        dpath = os.path.join(root, "DESIGN.md")
        if os.path.isfile(dpath):
            with open(dpath, encoding="utf-8") as fh:
                self.design = fh.read()
        regdir = os.path.join(root, "registry")
        if os.path.isdir(regdir):
            for f in sorted(os.listdir(regdir)):
                if f.endswith(".csv"):
                    with open(os.path.join(regdir, f), encoding="utf-8") as fh:
                        first = fh.readline().rstrip("\n")
                    self.registry.append(("registry/" + f, first))


# --------------------------------------------------------------------------
# Shared scanning helpers (mirror of rules/mod.rs)
# --------------------------------------------------------------------------


def line_of(mask, offset):
    return mask.count("\n", 0, offset) + 1


def brace_span(mask, open_idx):
    """Byte span of a {...} block starting at the `{` at open_idx."""
    depth = 0
    for k in range(open_idx, len(mask)):
        if mask[k] == "{":
            depth += 1
        elif mask[k] == "}":
            depth -= 1
            if depth == 0:
                return (open_idx, k + 1)
    return (open_idx, len(mask))


FN_RE = re.compile(r"\bfn\s+(\w+)")


def fn_spans(mask):
    """(name, sig_start, body_span) for every fn with a body."""
    out = []
    for m in FN_RE.finditer(mask):
        j = mask.find("{", m.end())
        semi = mask.find(";", m.end())
        if j == -1 or (semi != -1 and semi < j):
            continue  # trait method declaration without a body
        out.append((m.group(1), m.start(), brace_span(mask, j)))
    return out


def test_regions(mask):
    """Spans of #[cfg(test)]-gated items and #[test] fns."""
    spans = []
    for m in re.finditer(r"#\[\s*cfg\s*\(\s*test\s*\)\s*\]|#\[\s*test\s*\]", mask):
        j = mask.find("{", m.end())
        if j != -1:
            spans.append(brace_span(mask, j))
    return spans


def in_spans(offset, spans):
    return any(a <= offset < b for a, b in spans)


def impl_header_of(mask, offset):
    """Header text of the innermost `impl` block containing offset."""
    best = None
    for m in re.finditer(r"\bimpl\b", mask):
        if m.start() > offset:
            break
        j = mask.find("{", m.end())
        if j == -1:
            continue
        a, b = brace_span(mask, j)
        if a <= offset < b:
            best = mask[m.start() : j]
    return best


# --------------------------------------------------------------------------
# Findings + suppressions (mirror of suppress.rs / report.rs)
# --------------------------------------------------------------------------

RULES = {
    "R1": "safety",
    "R2": "alloc",
    "R3": "panic",
    "R4": "version",
    "R5": "consistency",
    "R6": "hygiene",
}
NAMES = {v: k for k, v in RULES.items()}

SUPPRESS_RE = re.compile(r"lint:\s*allow\((\w+)\)\s*:?\s*(.*)")


class Finding:
    def __init__(self, path, line, rule, message):
        self.path = path
        self.line = line
        self.rule = rule  # short name, e.g. "panic"
        self.message = message

    def render(self):
        return "%s:%d: %s(%s) %s" % (
            self.path,
            self.line,
            NAMES.get(self.rule, "LINT"),
            self.rule,
            self.message,
        )


def suppressions(sf, findings):
    """Inline suppression table for one file: rule -> set of covered
    lines. A `// lint: allow(<rule>): <reason>` covers its own line and
    the next one. Missing/empty reason or an unknown rule is itself a
    finding (under the meta-rule name `suppress`)."""
    table = {}
    for (line, text) in sf.lex.comments:
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rule, reason = m.group(1), m.group(2).strip()
        if rule not in NAMES:
            findings.append(
                Finding(sf.path, line, "suppress", "unknown rule '%s' in suppression" % rule)
            )
            continue
        if not reason:
            findings.append(
                Finding(sf.path, line, "suppress", "suppression for '%s' carries no reason" % rule)
            )
            continue
        table.setdefault(rule, set()).update((line, line + 1))
    return table


def load_baseline(root, findings):
    """`lint.baseline` at the repo root: `<rule> <path> :: <reason>` per
    line suppresses every finding of <rule> in <path>. Returns list of
    [rule, path, reason, hits]."""
    path = os.path.join(root, "lint.baseline")
    entries = []
    if not os.path.isfile(path):
        return entries
    with open(path, encoding="utf-8") as fh:
        for i, raw in enumerate(fh, 1):
            s = raw.strip()
            if not s or s.startswith("#"):
                continue
            head, sep, reason = s.partition("::")
            parts = head.split()
            if len(parts) != 2 or not sep or not reason.strip():
                findings.append(
                    Finding(
                        "lint.baseline",
                        i,
                        "suppress",
                        "malformed baseline entry (want `<rule> <path> :: <reason>`)",
                    )
                )
                continue
            rule, fpath = parts
            if rule not in NAMES:
                findings.append(
                    Finding("lint.baseline", i, "suppress", "unknown rule '%s'" % rule)
                )
                continue
            entries.append([rule, fpath, reason.strip(), 0, i])
    return entries


# --------------------------------------------------------------------------
# R1 safety: every unsafe site carries a SAFETY comment
# --------------------------------------------------------------------------


def is_attr_or_empty(line):
    t = line.strip()
    return t == "" or t.startswith("#[") or t.startswith("#!")


def rule_safety(sf, findings):
    mask = sf.lex.mask
    comment_lines = {}
    for (line, text) in sf.lex.comments:
        comment_lines.setdefault(line, []).append(text)
        for extra in range(text.count("\n")):
            comment_lines.setdefault(line + 1 + extra, []).append(text)

    def documented(line):
        # same-line trailing/leading comment, else walk up through the
        # contiguous block of comments and attributes directly above
        for probe in comment_lines.get(line, []):
            if "SAFETY:" in probe or "# Safety" in probe:
                return True
        l = line - 1
        while l >= 1:
            if l in comment_lines:
                if any("SAFETY:" in t or "# Safety" in t for t in comment_lines[l]):
                    return True
                l -= 1
                continue
            if l - 1 < len(sf.lines) and is_attr_or_empty(sf.lines[l - 1]) and sf.lines[l - 1].strip() != "":
                l -= 1
                continue
            break
        return False

    for m in re.finditer(r"\bunsafe\b", mask):
        line = line_of(mask, m.start())
        if not documented(line):
            findings.append(
                Finding(
                    sf.path,
                    line,
                    "safety",
                    "`unsafe` without an adjacent `// SAFETY:` (or `/// # Safety`) comment",
                )
            )


# --------------------------------------------------------------------------
# R2 alloc: no allocation constructs in hot-path functions
# --------------------------------------------------------------------------

ALLOC_PATTERNS = [
    (re.compile(r"\bVec\s*::\s*new\b"), "Vec::new"),
    (re.compile(r"\bvec\s*!"), "vec!"),
    (re.compile(r"\.\s*to_vec\s*\("), ".to_vec()"),
    (re.compile(r"\.\s*clone\s*\(\s*\)"), ".clone()"),
    (re.compile(r"\.\s*collect\b"), ".collect()"),
    (re.compile(r"\bBox\s*::\s*new\b"), "Box::new"),
    (re.compile(r"\bformat\s*!"), "format!"),
    (re.compile(r"\bString\s*::\s*from\b"), "String::from"),
]

KERNEL_FN = re.compile(r"^(stage_|fwd_|bwd_|lone_)")

# Operator-zoo kernels in ops/linear.rs (DESIGN.md §19): hot by prefix
# regardless of suffix, so a helper split out of a `*_into` kernel
# stays under the zero-allocation contract.
ZOO_FN = re.compile(r"^(lowrank_|blockshuffle_)")


def hot_functions(sf):
    """(fn name, body span) for the DESIGN.md §15 hot paths: `*_into`
    entry points everywhere, stage kernels in ops/backend*.rs, zoo
    kernels in ops/linear.rs, and `NativeExecutor::forward` in serve.rs."""
    mask = sf.lex.mask
    base = sf.path.rsplit("/", 1)[-1]
    tests = test_regions(mask)
    out = []
    for (name, sig_start, body) in fn_spans(mask):
        if in_spans(sig_start, tests):
            continue
        hot = name.endswith("_into")
        if not hot and base.startswith("backend") and KERNEL_FN.search(name):
            hot = True
        if not hot and base == "linear.rs" and ZOO_FN.search(name):
            hot = True
        if not hot and base == "serve.rs" and name == "forward":
            hdr = impl_header_of(mask, sig_start)
            hot = hdr is not None and "NativeExecutor" in hdr
        if hot:
            out.append((name, body))
    return out


def rule_alloc(sf, tree, findings, supp):
    """Suppressed hits are cross-checked against DESIGN.md §15: the
    suppression is only honored when the hot function is named in the
    §15 exception list (keeps the two in lockstep) — that secondary
    finding is NOT itself suppressible."""
    mask = sf.lex.mask
    design15 = ""
    if tree.design is not None:
        m = re.search(r"^## §15\b.*?(?=^## §|\Z)", tree.design, re.S | re.M)
        if m:
            design15 = m.group(0)
    covered = supp.get("alloc", set())
    for (name, (a, b)) in hot_functions(sf):
        body = mask[a:b]
        for (pat, label) in ALLOC_PATTERNS:
            for hit in pat.finditer(body):
                line = line_of(mask, a + hit.start())
                if line in covered:
                    if design15 and name not in design15:
                        findings.append(
                            Finding(
                                sf.path,
                                line,
                                "consistency",
                                "alloc suppression in `%s` not backed by the DESIGN.md §15 exception list" % name,
                            )
                        )
                    continue
                findings.append(
                    Finding(
                        sf.path,
                        line,
                        "alloc",
                        "%s in hot-path fn `%s` (zero-allocation contract, DESIGN.md §15)" % (label, name),
                    )
                )


# --------------------------------------------------------------------------
# R3 panic: serving/gateway/train worker threads must be panic-free
# --------------------------------------------------------------------------

PANIC_FILES = ("serve.rs", "gateway.rs", "train.rs")
PANIC_PATTERNS = [
    (re.compile(r"\.\s*unwrap\s*\(\s*\)"), ".unwrap()"),
    (re.compile(r"\.\s*expect\s*\("), ".expect("),
    (re.compile(r"\bpanic\s*!"), "panic!"),
]


def rule_panic(sf, findings):
    if sf.path.rsplit("/", 1)[-1] not in PANIC_FILES:
        return
    if "/tests/" in sf.path:  # integration-test crates may panic freely
        return
    mask = sf.lex.mask
    tests = test_regions(mask)
    for (pat, label) in PANIC_PATTERNS:
        for hit in pat.finditer(mask):
            if in_spans(hit.start(), tests):
                continue
            line = line_of(mask, hit.start())
            findings.append(
                Finding(
                    sf.path,
                    line,
                    "panic",
                    "%s in non-test serving/training code (a worker panic wedges the session, DESIGN.md §16)" % label,
                )
            )


# --------------------------------------------------------------------------
# R4 version: &mut params doors must bump params_version
# --------------------------------------------------------------------------

MUT_PARAMS = re.compile(r"&\s*mut\s+self\s*\.\s*params\b(?!_version)")
BUMP = re.compile(r"\bself\s*\.\s*params_version\s*\+=")


def rule_version(sf, findings):
    if not sf.path.endswith("ops/linear.rs"):
        return
    mask = sf.lex.mask
    m = re.search(r"\bimpl\s+LinearOp\b", mask)
    if not m:
        return
    j = mask.find("{", m.end())
    ia, ib = brace_span(mask, j)
    impl_body = mask[ia:ib]
    for (name, sig_start, (a, b)) in fn_spans(impl_body):
        body = impl_body[a:b]
        if MUT_PARAMS.search(body) and not BUMP.search(body):
            findings.append(
                Finding(
                    sf.path,
                    line_of(mask, ia + sig_start),
                    "version",
                    "`%s` hands out &mut params without bumping params_version (cache-invalidation contract, DESIGN.md §15)" % name,
                )
            )


# --------------------------------------------------------------------------
# R5 consistency: cross-file contracts
# --------------------------------------------------------------------------

CONST_DEF = re.compile(r"\bconst\s+((?:OP|ST)_\w+)\s*:\s*u8")


def rule_consistency_gateway(sf, findings):
    if sf.path.rsplit("/", 1)[-1] != "gateway.rs":
        return
    mask = sf.lex.mask
    consts = [(m.group(1), m.start()) for m in CONST_DEF.finditer(mask)]
    if not consts:
        return
    client = None
    m = re.search(r"\bimpl\s+GatewayClient\b", mask)
    if m:
        j = mask.find("{", m.end())
        client = brace_span(mask, j)
    tests = test_regions(mask)
    for (name, def_at) in consts:
        refs = [
            o
            for o in re.finditer(r"\b%s\b" % re.escape(name), mask)
            if not (def_at <= o.start() <= def_at + 60) and not in_spans(o.start(), tests)
        ]
        in_client = [o for o in refs if client and in_spans(o.start(), [client])]
        in_server = [o for o in refs if not client or not in_spans(o.start(), [client])]
        line = line_of(mask, def_at)
        if client and not in_client:
            findings.append(
                Finding(
                    sf.path,
                    line,
                    "consistency",
                    "wire constant `%s` is not referenced by GatewayClient (server/client protocol drift)" % name,
                )
            )
        if not in_server:
            findings.append(
                Finding(
                    sf.path,
                    line,
                    "consistency",
                    "wire constant `%s` is not referenced by the gateway server side" % name,
                )
            )


def rule_consistency_schema(sf, findings):
    if not sf.path.startswith("benches/"):
        return
    for (line, contents) in sf.lex.strings:
        if re.search(r"\bschema_version\b", contents):
            findings.append(
                Finding(
                    sf.path,
                    line,
                    "consistency",
                    "hand-rolled schema_version stamp; go through bench_args::json_header",
                )
            )


def rule_consistency_registry(tree, findings):
    magic = None
    magic_at = ("", 0)
    for sf in tree.files:
        if sf.path.endswith("src/ablate.rs"):
            m = re.search(r'const\s+REGISTRY_MAGIC\s*:\s*&str\s*=\s*"([^"]*)"', sf.text)
            if m:
                magic = m.group(1)
                magic_at = (sf.path, line_of(sf.text, m.start()))
    if magic is None:
        return
    for (path, first) in tree.registry:
        if first != magic:
            findings.append(
                Finding(
                    path,
                    1,
                    "consistency",
                    "registry header %r is not byte-equal to REGISTRY_MAGIC %r (%s:%d)"
                    % (first, magic, magic_at[0], magic_at[1]),
                )
            )


SECTION_REF = re.compile(r"DESIGN\.md\s+§§?(\d+)(?:\s*[-–]\s*§?(\d+))?")


def rule_consistency_design(sf, tree, findings):
    if tree.design is None:
        return
    sections = set(int(m.group(1)) for m in re.finditer(r"^## §(\d+)", tree.design, re.M))
    for (line, text) in sf.lex.comments:
        for m in SECTION_REF.finditer(text):
            for g in (m.group(1), m.group(2)):
                if g is not None and int(g) not in sections:
                    findings.append(
                        Finding(
                            sf.path,
                            line,
                            "consistency",
                            "comment references DESIGN.md §%s, which does not exist" % g,
                        )
                    )


# --------------------------------------------------------------------------
# R6 hygiene: bracket balance + unused `use`
# --------------------------------------------------------------------------

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {v: k for k, v in OPEN.items()}

# Traits routinely imported only for their methods / macro names the
# text search cannot see a bare identifier for (documented, DESIGN.md
# §18). Kept deliberately short.
TRAIT_METHOD_ALLOW = {"Read", "Write", "BufRead", "Seek", "FromStr", "Context", "Display"}


def rule_hygiene_balance(sf, findings):
    mask = sf.lex.mask
    stack = []
    for idx, ch in enumerate(mask):
        if ch in OPEN:
            stack.append((ch, idx))
        elif ch in CLOSE:
            if not stack or stack[-1][0] != CLOSE[ch]:
                findings.append(
                    Finding(sf.path, line_of(mask, idx), "hygiene", "unbalanced `%s`" % ch)
                )
                return
            stack.pop()
    if stack:
        ch, idx = stack[-1]
        findings.append(
            Finding(sf.path, line_of(mask, idx), "hygiene", "unclosed `%s`" % ch)
        )


USE_RE = re.compile(r"(?:^|\n)(\s*)(pub\s*(?:\([^)]*\)\s*)?)?use\s+([^;]+);", re.S)


def use_leaves(clause):
    """Leaf identifiers a `use` clause binds: the last path segment, the
    `as` alias, every member of a `{...}` group (recursively); `*` globs
    and `as _` bind nothing checkable."""
    clause = clause.strip()
    if clause.endswith("}"):
        b = clause.index("{")
        inner = clause[b + 1 : -1]
        prefix = clause[:b].rstrip(": \t\n")
        parts, depth, cur = [], 0, ""
        for ch in inner:
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(cur)
                cur = ""
            else:
                cur += ch
        parts.append(cur)
        out = []
        for p in parts:
            if not p.strip():
                continue
            if p.strip() == "self":
                seg = prefix.rsplit("::", 1)[-1].strip()
                if seg:
                    out.append(seg)
            else:
                out.extend(use_leaves(p))
        return out
    if " as " in clause:
        alias = clause.rsplit(" as ", 1)[1].strip()
        return [] if alias == "_" else [alias]
    leaf = clause.rsplit("::", 1)[-1].strip()
    if leaf in ("*", "self") or not leaf:
        return []
    return [leaf]


def rule_hygiene_unused_use(sf, findings):
    mask = sf.lex.mask
    spans = [(m.start(3), m.end()) for m in USE_RE.finditer(mask)]
    rest = list(mask)
    for a, b in spans:
        for k in range(a, b):
            if rest[k] != "\n":
                rest[k] = " "
    rest = "".join(rest)
    for m in USE_RE.finditer(mask):
        if m.group(2):  # pub use re-exports bind the public surface
            continue
        line = line_of(mask, m.start(3))
        for name in use_leaves(m.group(3)):
            if name in TRAIT_METHOD_ALLOW:
                continue
            if not re.search(r"\b%s\b" % re.escape(name), rest):
                findings.append(
                    Finding(sf.path, line, "hygiene", "unused import `%s`" % name)
                )


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lint_tree(root):
    tree = Tree(root)
    findings = []
    baseline = load_baseline(root, findings)
    supp_by_file = {}
    for sf in tree.files:
        supp = suppressions(sf, findings)
        supp_by_file[sf.path] = supp
        rule_safety(sf, findings)
        rule_alloc(sf, tree, findings, supp)
        rule_panic(sf, findings)
        rule_version(sf, findings)
        rule_consistency_gateway(sf, findings)
        rule_consistency_schema(sf, findings)
        rule_consistency_design(sf, tree, findings)
        rule_hygiene_balance(sf, findings)
        rule_hygiene_unused_use(sf, findings)
    rule_consistency_registry(tree, findings)
    # inline suppressions: a `lint: allow(<rule>)` covers its own line
    # and the next one, in its own file (R2's DESIGN-§15 cross-check ran
    # inside rule_alloc and is deliberately not re-suppressible here)
    active = []
    for f in findings:
        covered = supp_by_file.get(f.path, {}).get(f.rule, set())
        if f.line in covered:
            continue
        active.append(f)
    # baseline pass: a (rule, path) entry eats every matching finding;
    # an entry that eats nothing is stale and is itself a finding
    remaining = []
    for f in active:
        eaten = False
        for e in baseline:
            if e[0] == f.rule and e[1] == f.path:
                e[3] += 1
                eaten = True
        if not eaten:
            remaining.append(f)
    for e in baseline:
        if e[3] == 0:
            remaining.append(
                Finding("lint.baseline", e[4], "suppress", "stale baseline entry: %s %s" % (e[0], e[1]))
            )
    remaining.sort(key=lambda f: (f.path, f.line, f.rule))
    return remaining, len(findings) - len(remaining)


def main(argv):
    root = "."
    json_path = None
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--root" and i + 1 < len(argv):
            root = argv[i + 1]
            i += 2
        elif a == "--json" and i + 1 < len(argv):
            json_path = argv[i + 1]
            i += 2
        else:
            sys.stderr.write("usage: spm_lint.py [--root DIR] [--json PATH]\n")
            return 2
    active, _ = lint_tree(root)
    for f in active:
        print(f.render())
    if json_path:
        doc = {
            "tool": "spm-lint",
            "schema_version": 1,
            "findings": [
                {"file": f.path, "line": f.line, "rule": f.rule, "message": f.message}
                for f in active
            ],
        }
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=2)
            fh.write("\n")
    if active:
        print("spm-lint: %d finding(s)" % len(active))
        return 1
    print("spm-lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
