#!/usr/bin/env bash
# Tier-1 verify in one command: build + test + format check on the
# default (offline, dependency-free) workspace members. spm-runtime
# needs the XLA vendor set and is excluded from the default members;
# build it standalone with `cd rust/spm-runtime && cargo build`.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

# Format check. Non-fatal unless SPM_FMT_STRICT=1: rustfmt output can
# drift across toolchain versions and must not mask real build/test
# failures on machines with a different rustfmt.
if command -v rustfmt >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        if [ "${SPM_FMT_STRICT:-0}" = "1" ]; then
            echo "ci.sh: cargo fmt --check failed (SPM_FMT_STRICT=1)" >&2
            exit 1
        fi
        echo "ci.sh: cargo fmt --check reported drift (set SPM_FMT_STRICT=1 to fail on it)"
    fi
else
    echo "ci.sh: rustfmt not installed; skipping format check"
fi

# Lint check. Non-fatal unless SPM_CLIPPY_STRICT=1 (same split as the fmt
# gate: lint sets drift across toolchain versions, and a developer's older
# clippy must not mask real build/test failures). The CI workflow runs the
# same command strictly with its pinned stable toolchain.
if cargo clippy --version >/dev/null 2>&1; then
    if ! cargo clippy --all-targets -- -D warnings; then
        if [ "${SPM_CLIPPY_STRICT:-0}" = "1" ]; then
            echo "ci.sh: cargo clippy failed (SPM_CLIPPY_STRICT=1)" >&2
            exit 1
        fi
        echo "ci.sh: cargo clippy reported warnings (set SPM_CLIPPY_STRICT=1 to fail on them)"
    fi
else
    echo "ci.sh: clippy not installed; skipping lint check"
fi

echo "ci.sh: OK"
