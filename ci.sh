#!/usr/bin/env bash
# Tier-1 verify in one command: build + test + format check on the
# default (offline, dependency-free) workspace members. spm-runtime
# needs the XLA vendor set and is excluded from the default members;
# build it standalone with `cd rust/spm-runtime && cargo build`.
set -euo pipefail
cd "$(dirname "$0")"

# Repo-contract lint (spm-lint, DESIGN.md §18): prefers the Rust binary,
# falls back to the line-for-line Python mirror so the same checks run
# in containers with no Rust toolchain. `./ci.sh --lint` runs ONLY this
# (the toolchain-less entry point); the full flow runs it first below so
# contract drift fails before the expensive build+test passes.
run_spm_lint() {
    if command -v cargo >/dev/null 2>&1; then
        cargo run --release -q -p spm-lint -- --root .
    else
        echo "ci.sh: no cargo; linting via the Python mirror (tools/spm_lint.py)"
        python3 tools/spm_lint.py --root .
    fi
}
if [ "${1:-}" = "--lint" ]; then
    run_spm_lint
    exit 0
fi

# Extra cargo flags for the main build+test pass. The CI matrix simd leg
# passes "--features simd" here (with RUSTFLAGS pinning x86-64-v3) so the
# AVX2 stage backend is what the suite exercises; unquoted on purpose so
# the flags word-split.
SPM_CARGO_FEATURES="${SPM_CARGO_FEATURES:-}"

run_spm_lint

cargo build --release $SPM_CARGO_FEATURES
cargo test -q $SPM_CARGO_FEATURES

# Second test pass with the vectorized stage backend compiled in, so
# developer machines exercise what the CI simd matrix leg gates. Skipped
# (non-fatally, same split as the fmt/clippy gates) when the first pass
# already enabled it, when running as a CI matrix leg (SPM_EXEC set: the
# dedicated simd leg already covers this with stronger RUSTFLAGS, and
# duplicating it on the fused leg would double that leg's build+test
# time), or when the host is not x86_64 — the backend cfg's out there
# and the pass would just repeat the scalar suite. Test failures in this
# pass are real failures, never masked.
if [[ "$SPM_CARGO_FEATURES" == *simd* ]]; then
    echo "ci.sh: main pass already ran with the simd feature; skipping second pass"
elif [ -n "${SPM_EXEC:-}" ]; then
    echo "ci.sh: CI matrix leg (SPM_EXEC=$SPM_EXEC); simd pass is the simd leg's job"
elif [ "$(uname -m)" = "x86_64" ]; then
    cargo test -q --features simd
else
    echo "ci.sh: non-x86_64 host ($(uname -m)); skipping --features simd test pass"
fi

# Serving-engine smoke: all four ModelKinds through the same
# ServeEngine::native entry point; --check fails if any model did not
# serve every request (or reported an idle replica). The CI serve-smoke
# job runs the bigger pass and records the BENCH_serve.json artifact.
cargo run --release -p spm-coordinator $SPM_CARGO_FEATURES --example serve_bench -- \
    --requests 64 --clients 4 --replicas 2 --check

# Gateway smoke: the TCP front-end over loopback — closed-loop clients
# on both lanes, a mid-run checkpoint hot-swap, and a deliberate
# overload phase. --check gates zero steady-phase sheds, the p99
# budget, zero dropped in-flight requests across the swap, and that
# overload actually sheds (queue caps working) without one engine
# failure. The CI gateway-smoke job runs the bigger pass and records
# the BENCH_gateway.json artifact.
cargo run --release -p spm-coordinator $SPM_CARGO_FEATURES --example serve_bench -- \
    --gateway --requests 24 --clients 4 --replicas 2 --check

# Data-parallel training smoke: the TrainEngine over 2 replicas at a
# small width; --check gates loss-decreases-from-init at every replica
# count AND that the R=1 and R=2 parameter trajectories are
# bit-identical under pinned per-replica threads (the deterministic
# all-reduce contract, DESIGN.md §14). The CI train-smoke job runs the
# same pass and records the BENCH_train.json artifact.
cargo run --release -p spm-coordinator $SPM_CARGO_FEATURES --example train_bench -- \
    --n 32 --rows 16 --steps 4 --replicas 2 --check

# Ablation-harness smoke (DESIGN.md §17): the committed smoke plan
# through the native TrainEngine; --check gates bit-identical exact KPIs
# across a double run (pinned seeds/threads) and compares against any
# committed registry/smoke.csv baselines for this exec backend. The CI
# ablate-smoke job runs the same pass per matrix leg and records the
# ABLATE_smoke.json artifact.
cargo run --release -p spm-coordinator $SPM_CARGO_FEATURES --example ablate -- \
    --plan ablate/smoke.toml --check

# Operator-zoo ablation smoke (DESIGN.md §19): every LinearKind side by
# side at equal parameter budgets through the same harness and gates.
# The CI ablate-smoke job runs the same pass per matrix leg and records
# the ABLATE_zoo.json artifact.
cargo run --release -p spm-coordinator $SPM_CARGO_FEATURES --example ablate -- \
    --plan ablate/zoo.toml --check

# Format check. Non-fatal unless SPM_FMT_STRICT=1: rustfmt output can
# drift across toolchain versions and must not mask real build/test
# failures on machines with a different rustfmt.
if command -v rustfmt >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        if [ "${SPM_FMT_STRICT:-0}" = "1" ]; then
            echo "ci.sh: cargo fmt --check failed (SPM_FMT_STRICT=1)" >&2
            exit 1
        fi
        echo "ci.sh: cargo fmt --check reported drift (set SPM_FMT_STRICT=1 to fail on it)"
    fi
else
    echo "ci.sh: rustfmt not installed; skipping format check"
fi

# Lint check. Non-fatal unless SPM_CLIPPY_STRICT=1 (same split as the fmt
# gate: lint sets drift across toolchain versions, and a developer's older
# clippy must not mask real build/test failures). The CI workflow runs the
# same command strictly with its pinned stable toolchain.
if cargo clippy --version >/dev/null 2>&1; then
    # Inherits the leg's feature set so the simd matrix leg lints the
    # vectorized backend too.
    if ! cargo clippy --all-targets $SPM_CARGO_FEATURES -- -D warnings; then
        if [ "${SPM_CLIPPY_STRICT:-0}" = "1" ]; then
            echo "ci.sh: cargo clippy failed (SPM_CLIPPY_STRICT=1)" >&2
            exit 1
        fi
        echo "ci.sh: cargo clippy reported warnings (set SPM_CLIPPY_STRICT=1 to fail on them)"
    fi
else
    echo "ci.sh: clippy not installed; skipping lint check"
fi

echo "ci.sh: OK"
