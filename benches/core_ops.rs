//! Bench: raw operator complexity (paper §5) — native single-thread SPM
//! stage cost O(nL) vs dense matmul O(n^2), the SPM path comparison
//! (reference `spm.rs` closed form vs the planned row-wise path vs the
//! batch-fused stage kernels vs the simd backend where available,
//! DESIGN.md §11-§12), plus per-stage fwd/bwd micro timings.
//!
//! Also buildable as an example (same file, see spm-coordinator's
//! Cargo.toml) so CI can drive a reduced pass with plain `cargo run`:
//!
//! ```text
//! cargo run --release -p spm-coordinator --example core_ops -- \
//!     --sizes 256,1024 --json BENCH_core_ops.json --check
//! ```
//!
//! Flags: `--sizes a,b,c` widths for both tables (defaults when absent:
//! 256,512,1024,2048,4096 for the scaling table — the full PR-1 sweep —
//! and 256,1024,4096 for the SPM path table);
//! `--batch B` (default 64); `--json <path>` writes the scaling and
//! SPM-path tables as machine-readable JSON (the perf trajectory CI
//! records; a `"simd"` row family appears when the vectorized backend
//! ran); `--check` exits non-zero if the batch-fused planned path is
//! slower than the reference path — or loses forward parity — at n=1024
//! (falling back to the largest benched width when 1024 is not in
//! `--sizes`), and additionally, when the simd backend is active, if it
//! is slower than the scalar fused path or loses parity. The same gate
//! fails if the fused/simd `forward_into` hot path touches the
//! allocator in steady state (DESIGN.md §15; every path's measured
//! `allocs_per_iter` is reported in the table and JSON).

use spm_core::ops::{LinearCfg, LinearKind, LinearOp, SpmExec};
use spm_core::optim::Adam;
use spm_core::rng::Rng;
use spm_core::spm::{Spm, SpmSpec, Variant};
use spm_core::tensor::Mat;
use spm_coordinator::ablate::Gates;
use spm_coordinator::allocs::{self, CountingAlloc};
use spm_coordinator::bench_args::{json_header, json_num, BenchArgs};
use spm_coordinator::experiments::{self, ScalingRow};
use std::time::Instant;

// Count every allocator call so steady-state allocs_per_iter is a
// measured, gated number (DESIGN.md §15).
#[global_allocator]
static ALLOC_COUNTER: CountingAlloc = CountingAlloc;

fn ms_per(t0: Instant, reps: usize) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn time_ms(reps: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    ms_per(t0, reps)
}

/// One comparison row at a given width (general variant): reference vs
/// planned row-wise vs batch-fused vs (when available) the simd backend.
struct SpmRow {
    n: usize,
    variant: &'static str,
    ref_fwd: f64,
    ref_bwd: f64,
    row_fwd: f64,
    row_bwd: f64,
    fused_fwd: f64,
    fused_bwd: f64,
    /// vectorized-backend timings; None when the `simd` feature is off or
    /// AVX2/FMA were not detected (the exec downgraded to fused)
    simd_fwd: Option<f64>,
    simd_bwd: Option<f64>,
    /// forward max-abs-diff vs the reference path, per planned path
    row_fwd_diff: f32,
    fused_fwd_diff: f32,
    simd_fwd_diff: Option<f32>,
    /// steady-state allocator calls per forward, per path. The legacy
    /// paths allocate by design (fresh output + trace buffers); the
    /// fused/simd paths run `forward_into` through reused buffers and
    /// must report 0 (gated by `--check`).
    ref_allocs: f64,
    row_allocs: f64,
    fused_allocs: f64,
    simd_allocs: Option<f64>,
}

/// One operator-zoo row (DESIGN.md §19): a `LinearKind` benched at the
/// equal-budget defaults against SPM at the same width.
struct ZooRow {
    kind: &'static str,
    n: usize,
    params: usize,
    flops: usize,
    fwd: f64,
    /// steady-state allocator calls per `forward_into` (must be 0).
    allocs: f64,
    /// forward max-abs-diff vs an exact reference: the materialized
    /// dense map for lowrank/blockshuffle, the equivalent general-SPM
    /// op for butterfly; None for the kinds the SPM path table already
    /// cross-checks (dense, spm).
    diff: Option<f32>,
}

struct Args {
    /// `--sizes` when given; otherwise each table keeps its own default
    /// (scaling: the full PR-1 sweep at {256,512,1024,2048,4096}; the
    /// SPM path table: {256,1024,4096}).
    sizes: Option<Vec<usize>>,
    batch: usize,
    json: Option<String>,
    check: bool,
}

fn parse_args() -> Args {
    let a = BenchArgs::parse();
    Args {
        sizes: a.sizes(),
        batch: a.usize_flag("--batch", 64),
        json: a.json_path(),
        check: a.check(),
    }
}

fn bench_spm_row(n: usize, batch: usize) -> SpmRow {
    let variant = Variant::General;
    let mut rng = Rng::new(1);
    let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
    let spec = SpmSpec::new(n, variant);
    let reps = (60_000_000 / (batch * n * spec.num_stages).max(1)).clamp(3, 40);

    // identical seeds -> bit-equal parameters on all three paths
    let reference = Spm::new(spec);
    let ref_params = reference.init_params(&mut Rng::new(7));
    let mut adam = Adam::new(1e-3);
    let cfg = LinearCfg::spm(n, variant);
    let mut rowwise = LinearOp::new(cfg, &mut Rng::new(7), &mut adam);
    rowwise.set_exec(SpmExec::RowWise);
    let mut fused = LinearOp::new(cfg, &mut Rng::new(7), &mut adam);
    fused.set_exec(SpmExec::BatchFused);
    // simd downgrades to fused when unavailable; bench it only when the
    // vectorized backend actually stuck (otherwise the column would just
    // re-measure the fused path under another name)
    let mut simd = LinearOp::new(cfg, &mut Rng::new(7), &mut adam);
    simd.set_exec(SpmExec::Simd);
    let simd_on = simd.exec() == SpmExec::Simd;

    let ref_fwd = time_ms(reps, || {
        let _ = reference.forward(&ref_params, &x);
    });
    let row_fwd = time_ms(reps, || {
        let _ = rowwise.forward(&x);
    });
    let fused_fwd = time_ms(reps, || {
        let _ = fused.forward(&x);
    });
    let simd_fwd = simd_on.then(|| {
        time_ms(reps, || {
            let _ = simd.forward(&x);
        })
    });
    let ref_y = reference.forward(&ref_params, &x);
    let row_fwd_diff = rowwise.forward(&x).max_abs_diff(&ref_y);
    let fused_fwd_diff = fused.forward(&x).max_abs_diff(&ref_y);
    let simd_fwd_diff = simd_on.then(|| simd.forward(&x).max_abs_diff(&ref_y));

    let (y, ref_trace) = reference.forward_trace(&ref_params, &x);
    let ref_bwd = time_ms(reps, || {
        let _ = reference.backward(&ref_params, &x, &ref_trace, &y);
    });
    let (yr, row_trace) = rowwise.forward_train(&x);
    let row_bwd = time_ms(reps, || {
        let _ = rowwise.backward(&x, &row_trace, &yr);
    });
    let (yf, fused_trace) = fused.forward_train(&x);
    let fused_bwd = time_ms(reps, || {
        let _ = fused.backward(&x, &fused_trace, &yf);
    });
    let simd_bwd = simd_on.then(|| {
        let (ys, simd_trace) = simd.forward_train(&x);
        time_ms(reps, || {
            let _ = simd.backward(&x, &simd_trace, &ys);
        })
    });

    // steady-state allocator calls per forward: legacy paths through
    // their (allocating) entry points, fused/simd through `forward_into`
    // with a warm reused output — the serving hot path, expected 0
    const ALLOC_ITERS: u64 = 8;
    let ref_allocs = allocs::allocs_per_iter(ALLOC_ITERS, || {
        let _ = reference.forward(&ref_params, &x);
    });
    let row_allocs = allocs::allocs_per_iter(ALLOC_ITERS, || {
        let _ = rowwise.forward(&x);
    });
    let mut y_into = Mat { rows: 0, cols: 0, data: Vec::new() };
    fused.forward_into(&x, &mut y_into); // warm the reused buffer
    let fused_allocs = allocs::allocs_per_iter(ALLOC_ITERS, || {
        fused.forward_into(&x, &mut y_into);
    });
    let simd_allocs = simd_on.then(|| {
        simd.forward_into(&x, &mut y_into);
        allocs::allocs_per_iter(ALLOC_ITERS, || {
            simd.forward_into(&x, &mut y_into);
        })
    });

    SpmRow {
        n,
        variant: variant.name(),
        ref_fwd,
        ref_bwd,
        row_fwd,
        row_bwd,
        fused_fwd,
        fused_bwd,
        simd_fwd,
        simd_bwd,
        row_fwd_diff,
        fused_fwd_diff,
        simd_fwd_diff,
        ref_allocs,
        row_allocs,
        fused_allocs,
        simd_allocs,
    }
}

fn print_spm_table(rows: &[SpmRow], batch: usize) {
    println!("\nreference vs planned row-wise vs batch-fused vs simd SPM (batch={batch}, single thread, general variant; simd '-' = backend unavailable)");
    println!(
        "{:<7} {:>11} {:>11} {:>11} {:>11} {:>8} {:>8} {:>8} {:>11} {:>11} {:>11} {:>11} {:>8} {:>8} {:>8}",
        "n",
        "ref fwd",
        "row fwd",
        "fused fwd",
        "simd fwd",
        "f/ref x",
        "f/row x",
        "s/f x",
        "ref bwd",
        "row bwd",
        "fused bwd",
        "simd bwd",
        "f/ref x",
        "f/row x",
        "s/f x"
    );
    for r in rows {
        let opt_ms = |v: Option<f64>| v.map_or("-".to_string(), |t| format!("{t:.3}"));
        let opt_x =
            |num: f64, v: Option<f64>| v.map_or("-".to_string(), |t| format!("{:.2}x", num / t));
        println!(
            "{:<7} {:>11.3} {:>11.3} {:>11.3} {:>11} {:>7.2}x {:>7.2}x {:>8} {:>11.3} {:>11.3} {:>11.3} {:>11} {:>7.2}x {:>7.2}x {:>8}",
            r.n,
            r.ref_fwd,
            r.row_fwd,
            r.fused_fwd,
            opt_ms(r.simd_fwd),
            r.ref_fwd / r.fused_fwd,
            r.row_fwd / r.fused_fwd,
            opt_x(r.fused_fwd, r.simd_fwd),
            r.ref_bwd,
            r.row_bwd,
            r.fused_bwd,
            opt_ms(r.simd_bwd),
            r.ref_bwd / r.fused_bwd,
            r.row_bwd / r.fused_bwd,
            opt_x(r.fused_bwd, r.simd_bwd),
        );
    }
    println!("\nsteady-state allocator calls per forward (allocs_per_iter; fused/simd run forward_into through reused buffers and must be 0)");
    println!(
        "{:<7} {:>10} {:>10} {:>10} {:>10}",
        "n", "ref", "rowwise", "fused", "simd"
    );
    for r in rows {
        println!(
            "{:<7} {:>10.1} {:>10.1} {:>10.1} {:>10}",
            r.n,
            r.ref_allocs,
            r.row_allocs,
            r.fused_allocs,
            r.simd_allocs.map_or("-".to_string(), |a| format!("{a:.1}")),
        );
    }
}

/// Naive dense reference `y = x W^T + b` over a flat row-major `W`
/// (d_out x d_in) — the oracle the structured kinds are diffed against.
fn dense_reference(w: &[f32], bias: &[f32], x: &Mat) -> Mat {
    let (d_out, d_in) = (bias.len(), x.cols);
    let mut y = Mat::zeros(x.rows, d_out);
    for r in 0..x.rows {
        let xr = x.row(r);
        for i in 0..d_out {
            let wi = &w[i * d_in..(i + 1) * d_in];
            let mut acc = bias[i];
            for (wv, xv) in wi.iter().zip(xr) {
                acc += wv * xv;
            }
            *y.at_mut(r, i) = acc;
        }
    }
    y
}

/// Materialize a structured op's exact dense (W, b): `W = U V` for
/// lowrank, the block-diagonal scatter through the shuffle for
/// blockshuffle. Returns None for kinds without a closed dense form
/// here (spm/butterfly verify through the SPM reference path instead).
fn materialize_dense(op: &LinearOp) -> Option<(Vec<f32>, Vec<f32>)> {
    let (d_in, d_out) = (op.d_in(), op.d_out());
    let p = op.params();
    match op.kind() {
        LinearKind::LowRank => {
            let r = op.rank().expect("lowrank op has a rank");
            let (u, rest) = p.split_at(d_out * r);
            let (v, bias) = rest.split_at(r * d_in);
            let mut w = vec![0.0f32; d_out * d_in];
            for i in 0..d_out {
                for k in 0..r {
                    let uv = u[i * r + k];
                    for j in 0..d_in {
                        w[i * d_in + j] += uv * v[k * d_in + j];
                    }
                }
            }
            Some((w, bias.to_vec()))
        }
        LinearKind::BlockShuffle => {
            let bs = op.block_size().expect("blockshuffle op has a block size");
            let perm = op.shuffle().expect("blockshuffle op has a shuffle");
            let (blocks, bias) = p.split_at(d_in * bs);
            let mut w = vec![0.0f32; d_out * d_in];
            for base in (0..d_in).step_by(bs) {
                for i in 0..bs {
                    for j in 0..bs {
                        w[(base + i) * d_in + perm[base + j] as usize] =
                            blocks[(base + i) * bs + j];
                    }
                }
            }
            Some((w, bias.to_vec()))
        }
        _ => None,
    }
}

/// Bench one zoo kind at width `n`: forward_into timing, steady-state
/// allocations, and exact-reference parity (DESIGN.md §19).
fn bench_zoo_row(kind: LinearKind, n: usize, batch: usize) -> ZooRow {
    let mut rng = Rng::new(1);
    let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
    let cfg = LinearCfg { kind, ..LinearCfg::dense(n) }.with_seed(9);
    let mut adam = Adam::new(1e-3);
    let op = LinearOp::new(cfg, &mut Rng::new(7), &mut adam);
    let reps = (60_000_000 / (batch * op.flops_per_row()).max(1)).clamp(3, 40);

    let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
    op.forward_into(&x, &mut y); // warm the reused buffer
    let fwd = time_ms(reps, || {
        op.forward_into(&x, &mut y);
    });
    let allocs = allocs::allocs_per_iter(8, || {
        op.forward_into(&x, &mut y);
    });

    op.forward_into(&x, &mut y);
    let diff = match kind {
        LinearKind::Butterfly => {
            // bit-equal to a general SPM op pinned to the butterfly
            // schedule at the same seed
            let spm_cfg = LinearCfg::spm(n, Variant::General)
                .with_schedule(spm_core::pairing::Schedule::Butterfly)
                .with_seed(9);
            let twin = LinearOp::new(spm_cfg, &mut Rng::new(7), &mut adam);
            Some(twin.forward(&x).max_abs_diff(&y))
        }
        _ => materialize_dense(&op)
            .map(|(w, bias)| dense_reference(&w, &bias, &x).max_abs_diff(&y)),
    };

    ZooRow {
        kind: kind.name(),
        n,
        params: op.param_count(),
        flops: op.flops_per_row(),
        fwd,
        allocs,
        diff,
    }
}

fn print_zoo_table(rows: &[ZooRow], batch: usize) {
    let n = rows.first().map_or(0, |r| r.n);
    println!("\noperator zoo (n={n}, batch={batch}, single thread; lowrank/blockshuffle at the equal-budget defaults, diff vs exact reference, '-' = covered by the SPM path table)");
    println!(
        "{:<14} {:>9} {:>11} {:>11} {:>8} {:>12}",
        "kind", "params", "flops/row", "fwd ms", "allocs", "max|diff|"
    );
    for r in rows {
        println!(
            "{:<14} {:>9} {:>11} {:>11.3} {:>8.1} {:>12}",
            r.kind,
            r.params,
            r.flops,
            r.fwd,
            r.allocs,
            r.diff.map_or("-".to_string(), |d| format!("{d:.3e}")),
        );
    }
}

/// Hand-rolled JSON (the default workspace is dependency-free): one object
/// with the run setup, the §5 scaling rows, and the SPM path rows.
fn to_json(scaling: &[ScalingRow], rows: &[SpmRow], zoo: &[ZooRow], batch: usize) -> String {
    use std::fmt::Write as _;
    let mut s = json_header("core_ops");
    let _ = writeln!(s, "  \"batch\": {batch},");
    s.push_str("  \"core_scaling\": [\n");
    for (i, r) in scaling.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"n\": {}, \"dense_fwd_ms\": {:.6}, \"spm_fwd_ms\": {:.6}, \"ratio\": {}}}",
            r.n,
            r.dense_ms,
            r.spm_ms,
            json_num(r.dense_ms / r.spm_ms)
        );
        s.push_str(if i + 1 < scaling.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ],\n  \"planned_vs_reference\": [\n");
    let mut first = true;
    for r in rows {
        let mut paths: Vec<(&str, f64, f64, f32, f64)> = vec![
            ("reference", r.ref_fwd, r.ref_bwd, 0.0, r.ref_allocs),
            ("rowwise", r.row_fwd, r.row_bwd, r.row_fwd_diff, r.row_allocs),
            ("fused", r.fused_fwd, r.fused_bwd, r.fused_fwd_diff, r.fused_allocs),
        ];
        // the simd row family only exists where the backend ran — its
        // absence in the artifact is itself the "downgraded" signal
        if let (Some(sf), Some(sb), Some(sd), Some(sa)) =
            (r.simd_fwd, r.simd_bwd, r.simd_fwd_diff, r.simd_allocs)
        {
            paths.push(("simd", sf, sb, sd, sa));
        }
        for (path, fwd, bwd, diff, apfi) in paths {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            let _ = write!(
                s,
                "    {{\"n\": {}, \"variant\": \"{}\", \"path\": \"{}\", \"fwd_ms\": {:.6}, \"bwd_ms\": {:.6}, \"fwd_speedup_vs_ref\": {}, \"bwd_speedup_vs_ref\": {}, \"fwd_max_abs_diff_vs_ref\": {}, \"allocs_per_iter\": {}}}",
                r.n,
                r.variant,
                path,
                fwd,
                bwd,
                json_num(r.ref_fwd / fwd),
                json_num(r.ref_bwd / bwd),
                json_num(diff as f64),
                json_num(apfi)
            );
        }
    }
    s.push_str("\n  ],\n  \"operator_zoo\": [\n");
    for (i, r) in zoo.iter().enumerate() {
        let diff = r.diff.map_or("null".to_string(), |d| json_num(d as f64));
        let _ = write!(
            s,
            "    {{\"kind\": \"{}\", \"n\": {}, \"param_count\": {}, \"flops_per_row\": {}, \"fwd_ms\": {:.6}, \"allocs_per_iter\": {}, \"fwd_max_abs_diff_vs_ref\": {}}}",
            r.kind,
            r.n,
            r.params,
            r.flops,
            r.fwd,
            json_num(r.allocs),
            diff
        );
        s.push_str(if i + 1 < zoo.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// The CI gate: the batch-fused planned path must not be slower than the
/// reference path (and must keep forward parity) at n=1024, or at the
/// largest benched width when 1024 was not requested; when the simd
/// backend ran, it must additionally not be slower than the scalar fused
/// path and must keep parity too. Every threshold comes from the
/// declarative gates schema (`ablate/gates.toml`, DESIGN.md §17): the
/// `[core_ops]` relative margins absorb shared-runner noise (the fused
/// path wins by >1.5x when healthy, so anything inside the margin is a
/// real regression signal, not jitter).
fn check_trajectory(rows: &[SpmRow], gates: &Gates) -> Result<(), String> {
    let g = &gates.core_ops;
    let fused_margin = 1.0 + g.fused_vs_ref_rel;
    let simd_margin = 1.0 + g.simd_vs_fused_rel;
    let r = rows
        .iter()
        .find(|r| r.n == 1024)
        .or_else(|| rows.iter().max_by_key(|r| r.n))
        .ok_or("no SPM rows benched")?;
    // The CI simd matrix leg exports SPM_EXEC=simd: there the simd rows
    // MUST exist — a detection or feature-wiring regression must fail the
    // gate, not silently degrade it to a duplicate fused measurement.
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && r.simd_fwd.is_none() {
        return Err(format!(
            "SPM_EXEC=simd but the simd backend did not activate at n={} (feature off or AVX2/FMA undetected)",
            r.n
        ));
    }
    if r.fused_fwd > r.ref_fwd * fused_margin {
        return Err(format!(
            "planned (fused) forward slower than reference at n={}: {:.3} ms vs {:.3} ms",
            r.n, r.fused_fwd, r.ref_fwd
        ));
    }
    if !(r.fused_fwd_diff.is_finite() && (r.fused_fwd_diff as f64) < g.parity_abs) {
        return Err(format!(
            "fused forward parity broke at n={}: max|diff| = {:.3e}",
            r.n, r.fused_fwd_diff
        ));
    }
    // the zero-allocation steady-state gate (DESIGN.md §15): the fused
    // (and simd) forward_into hot path must not touch the allocator
    if r.fused_allocs > g.fused_allocs_max {
        return Err(format!(
            "fused forward_into allocated in steady state at n={}: {:.1} allocs/iter (cap {})",
            r.n, r.fused_allocs, g.fused_allocs_max
        ));
    }
    if let Some(sa) = r.simd_allocs {
        if sa > g.simd_allocs_max {
            return Err(format!(
                "simd forward_into allocated in steady state at n={}: {sa:.1} allocs/iter (cap {})",
                r.n, g.simd_allocs_max
            ));
        }
    }
    match (r.simd_fwd, r.simd_fwd_diff) {
        (Some(simd_fwd), Some(simd_diff)) => {
            if simd_fwd > r.fused_fwd * simd_margin {
                return Err(format!(
                    "simd forward slower than scalar fused at n={}: {:.3} ms vs {:.3} ms",
                    r.n, simd_fwd, r.fused_fwd
                ));
            }
            if !(simd_diff.is_finite() && (simd_diff as f64) < g.parity_abs) {
                return Err(format!(
                    "simd forward parity broke at n={}: max|diff| = {:.3e}",
                    r.n, simd_diff
                ));
            }
            println!(
                "\ncheck: fused fwd {:.3} ms <= ref fwd {:.3} ms and simd fwd {:.3} ms <= fused at n={}, max|diff| {:.3e}/{:.3e} — OK",
                r.fused_fwd, r.ref_fwd, simd_fwd, r.n, r.fused_fwd_diff, simd_diff
            );
        }
        _ => {
            println!(
                "\ncheck: fused fwd {:.3} ms <= ref fwd {:.3} ms at n={}, max|diff| {:.3e} — OK (simd backend not active)",
                r.fused_fwd, r.ref_fwd, r.n, r.fused_fwd_diff
            );
        }
    }
    Ok(())
}

/// The zoo leg of the gate: every structured kind must hold exact-
/// reference parity and keep its `forward_into` hot path allocation-free
/// in steady state (DESIGN.md §19; same caps as the fused SPM path).
fn check_zoo(zoo: &[ZooRow], gates: &Gates) -> Result<(), String> {
    let g = &gates.core_ops;
    for r in zoo {
        if let Some(d) = r.diff {
            if !(d.is_finite() && (d as f64) < g.parity_abs) {
                return Err(format!(
                    "{} forward parity broke at n={}: max|diff| = {d:.3e}",
                    r.kind, r.n
                ));
            }
        }
        if r.allocs > g.fused_allocs_max {
            return Err(format!(
                "{} forward_into allocated in steady state at n={}: {:.1} allocs/iter (cap {})",
                r.kind, r.n, r.allocs, g.fused_allocs_max
            ));
        }
    }
    println!("check: operator zoo parity + zero-alloc hold across {} kinds — OK", zoo.len());
    Ok(())
}

fn main() {
    let args = parse_args();
    let scaling_sizes = args.sizes.clone().unwrap_or_else(|| vec![256, 512, 1024, 2048, 4096]);
    let spm_sizes = args.sizes.clone().unwrap_or_else(|| vec![256, 1024, 4096]);

    // headline scaling table (§5: O(nL) vs O(n^2))
    let scaling = experiments::core_scaling_rows(&scaling_sizes, args.batch);
    println!("{}", experiments::render_scaling_table(&scaling, args.batch));

    spm_core::parallel::set_threads(1);

    // reference (spm.rs) vs planned row-wise vs planned batch-fused
    let spm_rows: Vec<SpmRow> = spm_sizes.iter().map(|&n| bench_spm_row(n, args.batch)).collect();
    print_spm_table(&spm_rows, args.batch);

    // the operator zoo at the smallest benched width (DESIGN.md §19)
    let zoo_n = spm_sizes.iter().copied().min().unwrap_or(256);
    let zoo_rows: Vec<ZooRow> =
        LinearKind::ALL.iter().map(|&k| bench_zoo_row(k, zoo_n, args.batch)).collect();
    print_zoo_table(&zoo_rows, args.batch);

    // per-variant stage micro-bench at the largest width (reference path)
    if let Some(&n) = spm_sizes.iter().max() {
        let batch = args.batch;
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        println!("\nper-op micro (n={n}, batch={batch}, single thread)");
        println!("{:<28} {:>10}", "op", "ms/call");
        for variant in [Variant::Rotation, Variant::General] {
            let op = Spm::new(SpmSpec::new(n, variant));
            let params = op.init_params(&mut rng);
            let stages = op.spec.num_stages;
            let fwd = time_ms(10, || {
                let _ = op.forward(&params, &x);
            });
            let (y, trace) = op.forward_trace(&params, &x);
            let bwd = time_ms(10, || {
                let _ = op.backward(&params, &x, &trace, &y);
            });
            println!("{:<28} {:>10.3}", format!("spm {} fwd (L={stages})", variant.name()), fwd);
            println!("{:<28} {:>10.3}", format!("spm {} bwd (L={stages})", variant.name()), bwd);
        }
    }
    spm_core::parallel::set_threads(0);

    if let Some(path) = &args.json {
        std::fs::write(path, to_json(&scaling, &spm_rows, &zoo_rows, args.batch))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote {path}");
    }

    if args.check {
        enforce_trajectory(&spm_rows, &zoo_rows);
    }
}

fn enforce_trajectory(rows: &[SpmRow], zoo: &[ZooRow]) {
    let gates = Gates::load_default().unwrap_or_else(|e| {
        eprintln!("check FAILED: {e}");
        std::process::exit(1);
    });
    println!("\ncheck thresholds: {}", gates.source);
    if let Err(msg) = check_trajectory(rows, &gates).and_then(|()| check_zoo(zoo, &gates)) {
        eprintln!("check FAILED: {msg}");
        std::process::exit(1);
    }
}
