//! Bench: raw operator complexity (paper §5) — native single-thread SPM
//! stage cost O(nL) vs dense matmul O(n^2), the planned-vs-reference SPM
//! comparison (flat-buffer `LinearOp`/`SpmPlan` against the `spm.rs`
//! closed-form path), plus per-stage fwd/bwd micro timings.

use spm_core::ops::{LinearCfg, LinearOp};
use spm_core::optim::Adam;
use spm_core::rng::Rng;
use spm_core::spm::{Spm, SpmSpec, Variant};
use spm_core::tensor::Mat;
use spm_coordinator::experiments;
use std::time::Instant;

fn ms_per(t0: Instant, reps: usize) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn main() {
    // headline scaling table (§5: O(nL) vs O(n^2))
    println!("{}", experiments::run_core_scaling(&[256, 512, 1024, 2048, 4096], 64));

    spm_core::parallel::set_threads(1);
    let batch = 64;

    // planned (LinearOp/SpmPlan flat buffers) vs reference (spm.rs) paths
    println!("\nplanned vs reference SPM (batch={batch}, single thread, general variant)");
    println!(
        "{:<8} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "n", "ref fwd ms", "plan fwd ms", "fwd x", "ref bwd ms", "plan bwd ms", "bwd x"
    );
    for n in [256usize, 1024, 4096] {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        let spec = SpmSpec::new(n, Variant::General);
        let reference = Spm::new(spec);
        let ref_params = reference.init_params(&mut rng);
        let mut adam = Adam::new(1e-3);
        let mut planned = LinearOp::new(LinearCfg::spm(n, Variant::General), &mut rng, &mut adam);
        let reps = (60_000_000 / (batch * n * spec.num_stages).max(1)).clamp(3, 40);

        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = reference.forward(&ref_params, &x);
        }
        let ref_fwd = ms_per(t0, reps);
        let t1 = Instant::now();
        for _ in 0..reps {
            let _ = planned.forward(&x);
        }
        let plan_fwd = ms_per(t1, reps);

        let (y, ref_trace) = reference.forward_trace(&ref_params, &x);
        let t2 = Instant::now();
        for _ in 0..reps {
            let _ = reference.backward(&ref_params, &x, &ref_trace, &y);
        }
        let ref_bwd = ms_per(t2, reps);
        let (yp, plan_trace) = planned.forward_train(&x);
        let t3 = Instant::now();
        for _ in 0..reps {
            let _ = planned.backward(&x, &plan_trace, &yp);
        }
        let plan_bwd = ms_per(t3, reps);

        println!(
            "{:<8} {:>12.3} {:>12.3} {:>7.2}x {:>12.3} {:>12.3} {:>7.2}x",
            n,
            ref_fwd,
            plan_fwd,
            ref_fwd / plan_fwd,
            ref_bwd,
            plan_bwd,
            ref_bwd / plan_bwd
        );
    }

    // per-variant stage micro-bench at n=4096 (reference path)
    let n = 4096;
    let mut rng = Rng::new(1);
    let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
    println!("\nper-op micro (n={n}, batch={batch}, single thread)");
    println!("{:<28} {:>10}", "op", "ms/call");
    for variant in [Variant::Rotation, Variant::General] {
        let op = Spm::new(SpmSpec::new(n, variant));
        let params = op.init_params(&mut rng);
        let reps = 10;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = op.forward(&params, &x);
        }
        let fwd = ms_per(t0, reps);
        let (y, trace) = op.forward_trace(&params, &x);
        let t1 = Instant::now();
        for _ in 0..reps {
            let _ = op.backward(&params, &x, &trace, &y);
        }
        let bwd = ms_per(t1, reps);
        println!("{:<28} {:>10.3}", format!("spm {} fwd (L=12)", variant.name()), fwd);
        println!("{:<28} {:>10.3}", format!("spm {} bwd (L=12)", variant.name()), bwd);
    }
    spm_core::parallel::set_threads(0);
}
