//! Bench: raw operator complexity (paper §5) — native single-thread SPM
//! stage cost O(nL) vs dense matmul O(n^2), plus per-stage fwd/bwd micro
//! timings for both variants.

use spm_core::rng::Rng;
use spm_core::spm::{Spm, SpmSpec, Variant};
use spm_core::tensor::Mat;
use spm_coordinator::experiments;
use std::time::Instant;

fn main() {
    // headline scaling table (§5: O(nL) vs O(n^2))
    println!("{}", experiments::run_core_scaling(&[256, 512, 1024, 2048, 4096], 64));

    // per-variant stage micro-bench at n=4096
    spm_core::parallel::set_threads(1);
    let n = 4096;
    let batch = 64;
    let mut rng = Rng::new(1);
    let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
    println!("\nper-op micro (n={n}, batch={batch}, single thread)");
    println!("{:<28} {:>10}", "op", "ms/call");
    for variant in [Variant::Rotation, Variant::General] {
        let op = Spm::new(SpmSpec::new(n, variant));
        let params = op.init_params(&mut rng);
        let reps = 10;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = op.forward(&params, &x);
        }
        let fwd = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let (y, trace) = op.forward_trace(&params, &x);
        let t1 = Instant::now();
        for _ in 0..reps {
            let _ = op.backward(&params, &x, &trace, &y);
        }
        let bwd = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!("{:<28} {:>10.3}", format!("spm {} fwd (L=12)", variant.name()), fwd);
        println!("{:<28} {:>10.3}", format!("spm {} bwd (L=12)", variant.name()), bwd);
    }
    spm_core::parallel::set_threads(0);
}
