//! Bench: the DESIGN.md ablations — stage depth L, pairing schedule, and
//! block variant at n=1024 on the teacher task.
//! Results -> results/abl_{depth,pairing,variant}.csv.

use spm_coordinator::RunConfig;
use spm_runtime::{drivers, Engine, Manifest};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}


fn env_steps(default: usize) -> usize {
    std::env::var("SPM_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> spm_coordinator::error::Result<()> {
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    for which in ["depth", "pairing", "variant"] {
        let cfg = RunConfig {
            steps: env_steps(120),
            eval_batches: 10,
            out_csv: repo_path(&format!("results/abl_{which}.csv")),
            ..Default::default()
        };
        let report = drivers::run_ablation(&engine, &man, which, &cfg)?;
        println!("{report}\n");
    }
    Ok(())
}
