//! Bench: the deterministic ablation harness (DESIGN.md §17) — expand a
//! committed `ablate/*.toml` plan into its cell grid, train every cell
//! through the native `TrainEngine` under pinned seeds and a pinned
//! single-thread budget, and report the KPI vector per cell (loss, acc,
//! param count, FLOPs/row, steady-state allocs/step, ns/row, rows/s).
//!
//! Replaces the old XLA-only `ablations` bench (which silently required
//! the excluded spm-runtime crate); the PJRT driver wrapper now lives in
//! `rust/spm-runtime/examples/ablations_xla.rs`.
//!
//! Also buildable as an example (same file, see spm-coordinator's
//! Cargo.toml) so CI can drive it with plain `cargo run`:
//!
//! ```text
//! cargo run --release -p spm-coordinator --example ablate -- \
//!     --plan ablate/smoke.toml --json ABLATE_smoke.json --check
//! ```
//!
//! Flags: `--plan <path>` the plan to run (default
//! `ablate/smoke.toml` at the repo root), `--registry <dir>` the
//! registry directory (default `registry/` at the repo root), `--json
//! <path>` writes the stable-schema report, `--update` appends this
//! run's rows to `registry/<plan>.csv` (append-only; commit the result
//! to move the baseline), `--check` the CI gate: runs the plan TWICE
//! and fails unless the exact KPIs are bit-identical, then compares the
//! fresh run against the latest matching registry rows per (plan hash,
//! exec, cell), failing on any out-of-tolerance KPI. Cells with no
//! committed baseline yet bootstrap (pass + warn).

use std::path::PathBuf;

use spm_coordinator::ablate::{
    self, check_against_registry, exact_rows, registry_append, registry_load, registry_path,
    report_json, run_plan, KpiClass, Plan, PlanReport, KPIS,
};
use spm_coordinator::allocs::CountingAlloc;
use spm_coordinator::bench_args::BenchArgs;
use spm_coordinator::metrics::{fmt_f, Table};

// Count every allocator call so allocs_per_step is a measured number
// (DESIGN.md §15). Only the bench binary installs this: the library and
// the integration tests stay on the system allocator.
#[global_allocator]
static ALLOC_COUNTER: CountingAlloc = CountingAlloc;

struct Args {
    plan: PathBuf,
    registry: PathBuf,
    json: Option<String>,
    check: bool,
    update: bool,
}

fn parse_args() -> Args {
    let a = BenchArgs::parse();
    let root = ablate::repo_root();
    Args {
        plan: a
            .str_opt("--plan")
            .map(PathBuf::from)
            .unwrap_or_else(|| root.join("ablate").join("smoke.toml")),
        registry: a
            .str_opt("--registry")
            .map(PathBuf::from)
            .unwrap_or_else(|| root.join("registry")),
        json: a.json_path(),
        check: a.check(),
        update: a.has("--update"),
    }
}

fn print_report(report: &PlanReport) {
    let mut headers = vec!["cell", "exec"];
    headers.extend(KPIS.iter().map(|k| k.name));
    let mut t = Table::new(&headers);
    for c in &report.cells {
        let mut row = vec![c.cell.id(), c.cell.exec.name().to_string()];
        for (spec, v) in KPIS.iter().zip(&c.kpis) {
            row.push(match spec.class {
                // exact values print in full — they are the bit-identity
                // contract, truncating them would hide drift
                KpiClass::Exact => format!("{v}"),
                KpiClass::Measured => fmt_f(*v, 1),
            });
        }
        t.row(row);
    }
    t.print();
    for s in &report.skipped {
        println!("skipped (backend unavailable here): {s}");
    }
}

fn die(msg: &str) -> ! {
    eprintln!("ablate FAILED: {msg}");
    std::process::exit(1);
}

fn main() {
    let args = parse_args();
    let plan = Plan::load(&args.plan).unwrap_or_else(|e| die(&e.to_string()));
    println!(
        "ablation plan '{}' (hash {}): n={}, {} steps x {} rows, seed {}\n",
        plan.name,
        plan.hash(),
        plan.n,
        plan.steps,
        plan.rows,
        plan.seed
    );

    let report = run_plan(&plan).unwrap_or_else(|e| die(&e.to_string()));
    print_report(&report);

    if let Some(path) = &args.json {
        std::fs::write(path, report_json(&plan, &report))
            .unwrap_or_else(|e| die(&format!("writing {path}: {e}")));
        println!("\nwrote {path}");
    }

    let reg_file = registry_path(&args.registry, &plan.name);
    if args.update {
        let appended =
            registry_append(&reg_file, &report).unwrap_or_else(|e| die(&e.to_string()));
        println!("\nappended {appended} row(s) to {} — commit it to move the baseline", reg_file.display());
    }

    if args.check {
        // gate 1: determinism — the same plan run twice in this process
        // must produce bit-identical exact KPIs (pinned seeds + pinned
        // single-thread budget make anything else a real bug)
        let second = run_plan(&plan).unwrap_or_else(|e| die(&e.to_string()));
        let (a, b) = (exact_rows(&report), exact_rows(&second));
        if a != b {
            for (x, y) in a.iter().zip(&b) {
                if x != y {
                    eprintln!("  first:  {x}\n  second: {y}");
                }
            }
            die("exact KPIs changed between two runs of the same plan — determinism broke");
        }
        println!("\ncheck: two runs bit-identical across {} cells", report.cells.len());

        // gate 2: regression vs the committed registry
        let rows = registry_load(&reg_file).unwrap_or_else(|e| die(&e.to_string()));
        let outcome = check_against_registry(&plan, &report, &rows);
        if outcome.bootstrapped > 0 {
            println!(
                "check: {} cell(s) have no baseline in {} yet (run --update and commit to arm the gate)",
                outcome.bootstrapped,
                reg_file.display()
            );
        }
        if !outcome.passed() {
            for f in &outcome.failures {
                eprintln!("  {f}");
            }
            die(&format!(
                "{} KPI regression(s) vs the registry baseline",
                outcome.failures.len()
            ));
        }
        println!(
            "check: {} cell(s) within tolerance of their registry baselines — OK",
            outcome.compared
        );
    }
}
