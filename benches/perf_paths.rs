//! Perf bench (EXPERIMENTS.md §Perf): quantifies the two L3 hot-path
//! optimizations:
//!   1. buffer-resident stepping (execute_b + untuple_result patch) vs the
//!      naive literal path (download+decompose+reupload all state per step);
//!   2. prefetched batch generation vs inline generation.

use std::time::Instant;

use spm_coordinator::experiments::DataSource;
use spm_data::batch::Prefetcher;
use spm_runtime::{DType, Engine, HostTensor, Manifest, TrainSession};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}


fn main() -> spm_coordinator::error::Result<()> {
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    let entry_name = std::env::var("SPM_PERF_ENTRY").unwrap_or("table2_spm_n2048".into());
    let steps: usize =
        std::env::var("SPM_PERF_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(20);

    // ---- path A: buffer-resident (the production path) --------------------
    let mut sess = TrainSession::new(&engine, &man, &entry_name, &["init", "train"])?;
    sess.init(0)?;
    let n = sess.entry.meta_usize("n")?;
    let batch = sess.entry.meta_usize("batch")?;
    let data = DataSource::Teacher { n, classes: 4, seed: 1 };
    let (x0, y0) = data.batch(0, batch, true);
    let x = HostTensor::F32(x0.data.clone());
    let y = HostTensor::from_labels(&y0);
    sess.train_step(&x, &y)?; // warmup
    let t0 = Instant::now();
    for _ in 0..steps {
        sess.train_step(&x, &y)?;
    }
    let buf_ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;

    // ---- path B: naive literal path (state round-trips the host) ----------
    let entry = man.entry(&entry_name)?.clone();
    let train = engine.load(&entry.artifact("train")?.file)?;
    let art = entry.artifact("train")?;
    // initial state as literals
    let mut state: Vec<xla::Literal> = Vec::new();
    for spec in &art.inputs[..3 * entry.nleaves + 1] {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match spec.dtype {
            DType::F32 => xla::Literal::vec1(&vec![0.05f32; spec.elements()]).reshape(&dims)?,
            DType::I32 => xla::Literal::vec1(&vec![0i32; spec.elements()]).reshape(&dims)?,
        };
        state.push(lit);
    }
    let x_lit = {
        let spec = &art.inputs[3 * entry.nleaves + 1];
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&x0.data).reshape(&dims)?
    };
    let y_lit = {
        let spec = &art.inputs[3 * entry.nleaves + 2];
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let yv: Vec<i32> = y0.iter().map(|&v| v as i32).collect();
        xla::Literal::vec1(&yv).reshape(&dims)?
    };
    let run_literal_step = |state: &mut Vec<xla::Literal>| -> anyhow::Result<f64> {
        let t = Instant::now();
        let mut args: Vec<&xla::Literal> = state.iter().collect();
        args.push(&x_lit);
        args.push(&y_lit);
        let outs = train.execute::<&xla::Literal>(&args)?;
        // download every state output back to host literals (the naive cost)
        let mut new_state = Vec::with_capacity(3 * entry.nleaves + 1);
        for b in outs[0][..3 * entry.nleaves + 1].iter() {
            new_state.push(b.to_literal_sync()?);
        }
        *state = new_state;
        Ok(t.elapsed().as_secs_f64() * 1e3)
    };
    run_literal_step(&mut state)?; // warmup
    let mut lit_ms = 0.0;
    for _ in 0..steps {
        lit_ms += run_literal_step(&mut state)?;
    }
    lit_ms /= steps as f64;

    // ---- prefetch vs inline batch generation ------------------------------
    let gen_steps = 50;
    let t0 = Instant::now();
    for i in 0..gen_steps {
        let _ = data.batch(i, batch, true);
    }
    let gen_ms = t0.elapsed().as_secs_f64() * 1e3 / gen_steps as f64;
    let data2 = data.clone();
    let mut pf = Prefetcher::new(gen_steps, 4, move |i| data2.batch(i, batch, true));
    let t1 = Instant::now();
    while let Some(b) = pf.next() {
        drop(b);
        // simulate a device step long enough for the producer to keep up
        std::thread::sleep(std::time::Duration::from_micros(500));
    }
    let pf_ms = t1.elapsed().as_secs_f64() * 1e3 / gen_steps as f64 - 0.5;

    println!("perf paths ({entry_name}, {steps} steps, batch {batch}, n {n})");
    println!("{:<44} {:>10.2} ms/step", "buffer-resident step (production)", buf_ms);
    println!("{:<44} {:>10.2} ms/step", "literal round-trip step (naive)", lit_ms);
    println!("{:<44} {:>10.2}x", "state-residency speedup", lit_ms / buf_ms);
    println!("{:<44} {:>10.2} ms", "batch generation inline", gen_ms);
    println!("{:<44} {:>10.2} ms", "batch generation prefetched (hidden)", pf_ms.max(0.0));
    Ok(())
}
