//! Bench: regenerates paper Table 2 (AG-News proxy, hashed features, L=12).
//! SPM_BENCH_STEPS overrides the step count. Results -> results/table2.csv.

use spm_coordinator::RunConfig;
use spm_runtime::{drivers, Engine, Manifest};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}


fn env_steps(default: usize) -> usize {
    std::env::var("SPM_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> spm_coordinator::error::Result<()> {
    let cfg = RunConfig {
        steps: env_steps(60),
        eval_batches: 20,
        out_csv: repo_path("results/table2.csv"),
        ..Default::default()
    };
    let widths = [2048usize, 4096];
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    let report = drivers::run_table2(&engine, &man, &widths, &cfg)?;
    println!("{report}");
    println!("paper Table 2 reference: Δacc +0.059/+0.065; speedup 3.63x/7.03x");
    Ok(())
}
