//! Bench: regenerates paper Tables 3 & 4 (char-LM d=4096, dense vs SPM
//! butterfly L=12). SPM_BENCH_STEPS overrides the step count (paper: 2000
//! steps, eval every 200). Results -> results/table3.csv, results/table4.csv.

use spm_coordinator::{experiments, RunConfig};
use spm_runtime::{drivers, Engine, Manifest};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}


fn env_steps(default: usize) -> usize {
    std::env::var("SPM_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> spm_coordinator::error::Result<()> {
    let steps = env_steps(30);
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    for (table, entry, csv) in [
        ("Table 3 (dense)", "charlm_dense_d4096", repo_path("results/table3.csv")),
        ("Table 4 (SPM)", "charlm_spm_d4096", repo_path("results/table4.csv")),
    ] {
        let cfg = RunConfig {
            steps,
            eval_every: (steps / 3).max(1),
            eval_batches: 10,
            out_csv: csv.clone(),
            ..Default::default()
        };
        let rows = drivers::run_charlm(&engine, &man, entry, &cfg)?;
        println!("{}", experiments::render_charlm_table(table, &rows));
    }
    println!("paper reference: dense ~22000 ms/step, BPC 3.08@800; SPM ~5700 ms/step, BPC 2.98@1000");
    Ok(())
}
