//! Bench: regenerates paper Table 1 (compositional teacher width sweep).
//! Steps default to a CI-friendly count; set SPM_BENCH_STEPS=1200 for the
//! paper's full schedule. Results land in results/table1.csv.

use spm_coordinator::RunConfig;
use spm_runtime::{drivers, Engine, Manifest};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}


fn env_steps(default: usize) -> usize {
    std::env::var("SPM_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> spm_coordinator::error::Result<()> {
    let cfg = RunConfig {
        steps: env_steps(120),
        eval_batches: 20,
        out_csv: repo_path("results/table1.csv"),
        ..Default::default()
    };
    let widths = [256usize, 512, 1024, 2048];
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    let report = drivers::run_table1(&engine, &man, &widths, &cfg)?;
    println!("{report}");
    println!("paper Table 1 reference: Δacc +0.22/+0.16/+0.05/+0.24; speedup 0.51x/1.07x/1.81x/3.42x");
    Ok(())
}
