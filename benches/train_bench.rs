//! Bench: the data-parallel TrainEngine (DESIGN.md §14) — one epoch of
//! teacher-student mlp training per replica count, same microbatch
//! stream, same group size, so the table isolates what replicas buy in
//! wall-clock while the parameter trajectory stays fixed.
//!
//! Also buildable as an example (same file, see spm-coordinator's
//! Cargo.toml) so CI can drive a reduced pass with plain `cargo run`:
//!
//! ```text
//! cargo run --release -p spm-coordinator --example train_bench -- \
//!     --n 48 --rows 32 --steps 5 --replicas 2 --json BENCH_train.json --check
//! ```
//!
//! Flags: `--n N` mixing width (default 1024), `--rows B` rows per
//! microbatch (default 64), `--steps S` optimizer steps per replica
//! count (default 8), `--replicas R` the largest replica count swept
//! (default 4; the sweep is 1, 2, 4, ... up to R), `--json <path>`
//! writes the throughput trajectory as machine-readable JSON, `--check`
//! exits non-zero unless every replica count reduced the loss from
//! init, the R=1 and R=max trajectories are bit-identical under pinned
//! per-replica threads (the deterministic-reduction gate), the R=1
//! steady-state step stays at its documented allocation floor (the
//! DESIGN.md §15 gate, reported as `allocs_per_iter` in the table and
//! JSON), and — at bench scale — the largest replica count clears the
//! speedup floor. All three caps come from the declarative gates schema
//! (`[train]` in `ablate/gates.toml`, DESIGN.md §17).

use spm_core::models::api::{Model, ModelCfg, ModelKind};
use spm_core::ops::{backend, LinearCfg, SpmExec};
use spm_core::parallel;
use spm_core::spm::Variant;
use spm_coordinator::ablate::Gates;
use spm_coordinator::allocs::{self, CountingAlloc};
use spm_coordinator::bench_args::{env_exec, json_header, json_num, BenchArgs};
use spm_coordinator::experiments::DataSource;
use spm_coordinator::metrics::{fmt_f, Table};
use spm_coordinator::train::{TrainBatch, TrainEngine, TrainReport};

// Count every allocator call so steady-state allocs_per_iter is a
// measured, gated number (DESIGN.md §15).
#[global_allocator]
static ALLOC_COUNTER: CountingAlloc = CountingAlloc;

struct Args {
    n: usize,
    rows: usize,
    steps: usize,
    replicas: usize,
    json: Option<String>,
    check: bool,
}

fn parse_args() -> Args {
    let a = BenchArgs::parse();
    Args {
        n: a.usize_flag("--n", 1024).max(2),
        rows: a.usize_flag("--rows", 64).max(1),
        steps: a.usize_flag("--steps", 8).max(1),
        replicas: a.usize_flag("--replicas", 4).max(1),
        json: a.json_path(),
        check: a.check(),
    }
}

fn model_cfg(n: usize, exec: SpmExec) -> ModelCfg {
    ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(n, Variant::General))
        .with_classes(10)
        .with_seed(7)
        .with_exec(exec)
}

/// 1, 2, 4, ... up to and including `max`.
fn replica_sweep(max: usize) -> Vec<usize> {
    let mut sweep = Vec::new();
    let mut r = 1;
    while r < max {
        sweep.push(r);
        r *= 2;
    }
    sweep.push(max);
    sweep
}

/// The epoch's microbatch stream — identical for every replica count.
fn make_batches(data: &DataSource, count: usize, rows: usize) -> Vec<TrainBatch> {
    (0..count)
        .map(|m| {
            let (x, y) = data.batch(m, rows, true);
            TrainBatch::labels(x, y)
        })
        .collect()
}

struct BenchRow {
    replicas: usize,
    threads_per_replica: usize,
    loss_before: f32,
    loss_after: f32,
    report: TrainReport,
    speedup: f64,
    /// steady-state allocator calls per 2-microbatch optimizer step
    /// under a pinned thread budget of 1 (DESIGN.md §15). R=1 runs the
    /// in-place reduce and must stay near zero (gated by `--check`; the
    /// expected count is 2: one trace-handle Vec per SPM General
    /// `forward_train` per microbatch). R>1 spawns scoped replica
    /// workers and snapshot deals, which allocate by design — the
    /// column documents the cost instead of gating it.
    allocs_per_step: f64,
}

fn flat_params(model: &dyn Model) -> Vec<f32> {
    let mut out = Vec::new();
    model.visit_params(&mut |_n, p| out.extend_from_slice(p));
    out
}

fn bench_replicas(
    cfg: &ModelCfg,
    replicas: usize,
    accum: usize,
    batches: &[TrainBatch],
    eval: &TrainBatch,
) -> BenchRow {
    let mut engine = TrainEngine::from_cfg(cfg, replicas).with_accum(accum);
    let threads_per_replica = engine.threads_per_replica();
    let (loss_before, _a) = engine.model().evaluate(&eval.x, &eval.target.as_target());
    let report = engine.train_epoch(batches);
    let (loss_after, _a) = engine.model().evaluate(&eval.x, &eval.target.as_target());

    // steady-state allocs per step: warm the 2-microbatch group path on
    // the (already hot) engine, then count; the pinned budget keeps the
    // kernels inline so the count reflects the workspaces, not spawns
    let probe = &batches[..batches.len().min(2).max(1)];
    let allocs_per_step = parallel::with_thread_budget(1, || {
        for _ in 0..2 {
            engine.step(probe);
        }
        allocs::allocs_per_iter(2, || {
            engine.step(probe);
        })
    });

    BenchRow {
        replicas,
        threads_per_replica,
        loss_before,
        loss_after,
        report,
        speedup: 1.0,
        allocs_per_step,
    }
}

/// The deterministic-reduction gate: R=1 vs R=max under pinned
/// per-replica threads and a fixed group size must produce
/// bit-identical parameters.
fn invariance_holds(cfg: &ModelCfg, rmax: usize, batches: &[TrainBatch]) -> bool {
    let probe = batches.len().min(2 * rmax.max(1));
    let run = |replicas: usize| -> Vec<f32> {
        let mut engine = TrainEngine::from_cfg(cfg, replicas)
            .with_accum(rmax)
            .with_threads_per_replica(1);
        engine.train_epoch(&batches[..probe]);
        flat_params(engine.model())
    };
    run(1) == run(rmax)
}

fn print_table(rows: &[BenchRow]) {
    let mut t = Table::new(&[
        "replicas",
        "threads/rep",
        "steps",
        "microbatches",
        "mean loss",
        "eval init",
        "eval final",
        "rows/s",
        "speedup",
        "allocs/step",
    ]);
    for r in rows {
        t.row(vec![
            r.replicas.to_string(),
            r.threads_per_replica.to_string(),
            r.report.steps.to_string(),
            r.report.microbatches.to_string(),
            fmt_f(r.report.mean_loss, 4),
            fmt_f(r.loss_before as f64, 4),
            fmt_f(r.loss_after as f64, 4),
            fmt_f(r.report.rows_per_sec, 0),
            format!("{:.2}x", r.speedup),
            fmt_f(r.allocs_per_step, 1),
        ]);
    }
    t.print();
}

/// Hand-rolled JSON (the default workspace is dependency-free): the run
/// setup plus one row per replica count.
fn to_json(rows: &[BenchRow], args: &Args, exec: SpmExec, invariant: bool) -> String {
    use std::fmt::Write as _;
    let mut s = json_header("train");
    let _ = writeln!(s, "  \"exec\": \"{}\",", exec.name());
    let _ = writeln!(s, "  \"n\": {},", args.n);
    let _ = writeln!(s, "  \"rows_per_microbatch\": {},", args.rows);
    let _ = writeln!(s, "  \"steps\": {},", args.steps);
    let _ = writeln!(s, "  \"max_replicas\": {},", args.replicas);
    let _ = writeln!(s, "  \"r_invariant\": {invariant},");
    s.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"replicas\": {}, \"threads_per_replica\": {}, \"steps\": {}, \"microbatches\": {}, \"mean_loss\": {}, \"loss_before\": {}, \"loss_after\": {}, \"rows_per_sec\": {}, \"speedup\": {}, \"allocs_per_iter\": {}}}",
            r.replicas,
            r.threads_per_replica,
            r.report.steps,
            r.report.microbatches,
            json_num(r.report.mean_loss),
            json_num(r.loss_before as f64),
            json_num(r.loss_after as f64),
            json_num(r.report.rows_per_sec),
            json_num(r.speedup),
            json_num(r.allocs_per_step)
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// The CI gate: loss must decrease from init at every replica count,
/// the trajectory must be replica-count invariant, the simd leg must
/// actually train vectorized, and at bench scale (n >= 1024) the
/// largest replica count must clear 1.5x single-replica throughput.
fn check_rows(rows: &[BenchRow], args: &Args, invariant: bool, gates: &Gates) -> Result<(), String> {
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && !backend::simd_available() {
        return Err(
            "SPM_EXEC=simd but the simd backend did not activate (feature off or AVX2/FMA \
             undetected) — the train smoke would only re-measure the fused path"
                .into(),
        );
    }
    for r in rows {
        if !(r.loss_after < r.loss_before) {
            return Err(format!(
                "R={}: loss did not decrease from init ({} -> {})",
                r.replicas, r.loss_before, r.loss_after
            ));
        }
        if !(r.report.rows_per_sec > 0.0) {
            return Err(format!("R={}: zero throughput", r.replicas));
        }
    }
    // the zero-allocation steady-state gate (DESIGN.md §15, cap from the
    // gates schema): the single-replica in-place reduce step must stay
    // at its documented floor — 1 trace-handle Vec per SPM General
    // forward_train per microbatch, with small headroom
    let r1 = &rows[0];
    if r1.replicas == 1 && r1.allocs_per_step > gates.train.r1_allocs_max {
        return Err(format!(
            "R=1 steady-state step allocated {:.1} times (cap {}: one trace-handle Vec per \
             microbatch plus headroom)",
            r1.allocs_per_step, gates.train.r1_allocs_max
        ));
    }
    if !invariant {
        return Err(format!(
            "R=1 vs R={} parameter trajectories diverged under pinned threads — the \
             all-reduce is not deterministic",
            args.replicas
        ));
    }
    if args.n >= gates.train.speedup_min_n && args.replicas > 1 {
        let last = rows.last().unwrap();
        if last.speedup < gates.train.min_speedup {
            return Err(format!(
                "R={} epoch throughput is only {:.2}x single-replica (need >= {}x at n={})",
                last.replicas, last.speedup, gates.train.min_speedup, args.n
            ));
        }
    }
    Ok(())
}

fn main() {
    let args = parse_args();
    let exec = env_exec();
    let rmax = args.replicas;
    let microbatches = args.steps * rmax;
    println!(
        "train engine: mlp n={}, {} microbatches x {} rows, accum {}, replicas {:?}, exec {}\n",
        args.n,
        microbatches,
        args.rows,
        rmax,
        replica_sweep(rmax),
        exec.name()
    );
    let cfg = model_cfg(args.n, exec);
    let data = DataSource::Teacher { n: args.n, classes: 10, seed: 7 };
    let batches = make_batches(&data, microbatches, args.rows);
    let (ex, ey) = data.batch(0, args.rows, false);
    let eval = TrainBatch::labels(ex, ey);

    let mut rows: Vec<BenchRow> = replica_sweep(rmax)
        .into_iter()
        .map(|r| bench_replicas(&cfg, r, rmax, &batches, &eval))
        .collect();
    let base = rows[0].report.rows_per_sec;
    for r in rows.iter_mut() {
        r.speedup = if base > 0.0 { r.report.rows_per_sec / base } else { 0.0 };
    }
    print_table(&rows);

    let invariant = invariance_holds(&cfg, rmax, &batches);
    println!(
        "\nR=1 vs R={rmax} trajectory (pinned threads): {}",
        if invariant { "bit-identical" } else { "DIVERGED" }
    );

    if let Some(path) = &args.json {
        std::fs::write(path, to_json(&rows, &args, exec, invariant))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }

    if args.check {
        let gates = Gates::load_default().unwrap_or_else(|e| {
            eprintln!("check FAILED: {e}");
            std::process::exit(1);
        });
        println!("check thresholds: {}", gates.source);
        match check_rows(&rows, &args, invariant, &gates) {
            Ok(()) => println!(
                "check: loss decreased at every replica count and the reduction is \
                 deterministic — OK"
            ),
            Err(msg) => {
                eprintln!("check FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}
