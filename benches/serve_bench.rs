//! Bench: the deadline-batched serving engine (DESIGN.md §13) over every
//! `ModelKind` — all four architectures through the same
//! `ServeEngine::native(model)` entry point, with replica sharding.
//!
//! Also buildable as an example (same file, see spm-coordinator's
//! Cargo.toml) so CI can drive a reduced pass with plain `cargo run`:
//!
//! ```text
//! cargo run --release -p spm-coordinator --example serve_bench -- \
//!     --requests 97 --clients 4 --json BENCH_serve.json --check
//! ```
//!
//! Flags: `--requests N` (default 256), `--clients C` (default 8),
//! `--batch B` micro-batch cap (default 16), `--wait-us W` deadline
//! before a partial batch flushes (default 200), `--replicas R` native
//! replicas per model (default 2), `--json <path>` writes the per-model
//! serving trajectory as machine-readable JSON, `--check` exits non-zero
//! if any model failed to serve EVERY request, reported zero throughput,
//! an idle replica (the all-requests-served + sharding gate CI
//! enforces), or a warm executor micro-batch that touched the allocator
//! (the DESIGN.md §15 zero-allocation steady-state gate, reported as
//! `allocs_per_iter` in the table and JSON).

use spm_core::models::api::{build_model, ModelCfg, ModelKind};
use spm_core::ops::{backend, LinearCfg, SpmExec};
use spm_core::parallel;
use spm_core::spm::Variant;
use spm_coordinator::allocs::{self, CountingAlloc};
use spm_coordinator::metrics::{fmt_f, Table};
use spm_coordinator::serve::{Executor, NativeExecutor, ServeEngine, ServeReport, Workload};

// Count every allocator call so steady-state allocs_per_iter is a
// measured, gated number (DESIGN.md §15).
#[global_allocator]
static ALLOC_COUNTER: CountingAlloc = CountingAlloc;

struct Args {
    requests: usize,
    clients: usize,
    batch: usize,
    wait_us: u64,
    replicas: usize,
    json: Option<String>,
    check: bool,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().collect();
    let get = |key: &str| argv.iter().position(|a| a == key).and_then(|i| argv.get(i + 1));
    let usize_flag = |key: &str, default: usize| match get(key) {
        Some(s) => s.parse().unwrap_or_else(|_| panic!("{key}: bad count")),
        None => default,
    };
    Args {
        requests: usize_flag("--requests", 256),
        clients: usize_flag("--clients", 8),
        batch: usize_flag("--batch", 16),
        wait_us: get("--wait-us")
            .map(|s| s.parse().expect("--wait-us: bad micros"))
            .unwrap_or(200),
        replicas: usize_flag("--replicas", 2).max(1),
        json: get("--json").cloned(),
        check: argv.iter().any(|a| a == "--check"),
    }
}

/// The benched zoo: one small-but-real config per architecture. `exec`
/// selects the SPM stage-loop path on every owned op — the CI matrix
/// exports `SPM_EXEC` so the simd leg serves through the vectorized
/// backend instead of re-measuring the fused path under another name.
fn model_cfg(kind: ModelKind, exec: SpmExec) -> ModelCfg {
    let (n, heads, seq_len, classes) = match kind {
        ModelKind::Mlp => (64, 1, 1, 10),
        ModelKind::Gru => (32, 1, 8, 10),
        ModelKind::CharLm => (64, 1, 1, 0),
        ModelKind::Attention => (64, 4, 8, 0),
    };
    ModelCfg::new(kind, LinearCfg::spm(n, Variant::General))
        .with_classes(classes.max(2))
        .with_heads(heads)
        .with_seq_len(seq_len)
        .with_seed(7)
        .with_exec(exec)
}

/// The exec path this run serves with: `SPM_EXEC` when set (the CI
/// matrix contract — bad names are an error, not a silent default),
/// otherwise the fused default.
fn serve_exec() -> SpmExec {
    match std::env::var("SPM_EXEC") {
        Ok(name) => SpmExec::parse(&name)
            .unwrap_or_else(|| panic!("SPM_EXEC '{name}' is not an exec mode")),
        Err(_) => SpmExec::default(),
    }
}

struct BenchRow {
    kind: ModelKind,
    d_in: usize,
    params: usize,
    report: ServeReport,
    /// steady-state allocator calls per executor micro-batch on the
    /// router's batch-assembly ping-pong (DESIGN.md §15) — must be 0
    allocs_per_iter: f64,
}

/// One router iteration against a native executor, mimicking the serve
/// engine's batch-assembly ping-pong: take the pool, refill it with the
/// batch's rows, forward, keep the returned buffer as the next pool.
fn exec_iter(kind: ModelKind, exec: &mut NativeExecutor, rows: usize, pool: &mut Vec<f32>) {
    let width = exec.width();
    let mut flat = std::mem::take(pool);
    flat.clear();
    flat.resize(rows * width, 0.0);
    for (i, v) in flat.iter_mut().enumerate() {
        // charlm rows carry byte tokens, everything else small reals
        *v = match kind {
            ModelKind::CharLm => 97.0 + (i % 3) as f32,
            _ => ((i * 37 % 11) as f32) * 0.1 - 0.5,
        };
    }
    let out = exec.forward(rows, flat).expect("executor forward");
    *pool = out;
}

/// Measured steady-state allocs per served micro-batch: warm the
/// executor + pool pair, then count a batch-cap-sized iteration on one
/// thread (the engine's workers drive the identical path).
fn steady_allocs(kind: ModelKind, cfg: &ModelCfg, rows: usize) -> f64 {
    let mut exec = NativeExecutor::new(build_model(cfg), rows.max(1));
    let mut pool: Vec<f32> = Vec::new();
    parallel::with_thread_budget(1, || {
        for _ in 0..4 {
            exec_iter(kind, &mut exec, rows.max(1), &mut pool);
        }
        allocs::allocs_per_iter(4, || exec_iter(kind, &mut exec, rows.max(1), &mut pool))
    })
}

fn bench_kind(kind: ModelKind, exec: SpmExec, args: &Args) -> BenchRow {
    let cfg = model_cfg(kind, exec);
    let probe = build_model(&cfg);
    let (d_in, params) = (probe.d_in(), probe.param_count());
    let mut engine = ServeEngine::native(probe)
        .with_max_batch(args.batch)
        .with_max_wait_us(args.wait_us);
    for _ in 1..args.replicas {
        engine = engine.with_replica(build_model(&cfg));
    }
    let workload = Workload { num_requests: args.requests, num_clients: args.clients, seed: 11 };
    let report = engine
        .run(&workload)
        .unwrap_or_else(|e| panic!("{}: serve failed: {e}", kind.name()));
    let allocs_per_iter = steady_allocs(kind, &cfg, args.batch);
    BenchRow { kind, d_in, params, report, allocs_per_iter }
}

fn print_table(rows: &[BenchRow]) {
    let mut t = Table::new(&[
        "model",
        "d_in",
        "params",
        "requests",
        "batches",
        "fill",
        "queue ms",
        "exec ms",
        "p50 ms",
        "p99 ms",
        "req/s",
        "allocs/iter",
    ]);
    for r in rows {
        t.row(vec![
            r.kind.name().to_string(),
            r.d_in.to_string(),
            r.params.to_string(),
            r.report.requests.to_string(),
            r.report.batches.to_string(),
            fmt_f(r.report.mean_batch_fill, 1),
            fmt_f(r.report.mean_queue_wait_ms, 3),
            fmt_f(r.report.mean_exec_ms, 3),
            fmt_f(r.report.p50_ms, 3),
            fmt_f(r.report.p99_ms, 3),
            fmt_f(r.report.throughput_rps, 0),
            fmt_f(r.allocs_per_iter, 1),
        ]);
    }
    t.print();
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".into()
    }
}

/// Hand-rolled JSON (the default workspace is dependency-free): the run
/// setup plus one row per served model.
fn to_json(rows: &[BenchRow], args: &Args, exec: SpmExec) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"serve\",\n");
    let _ = writeln!(s, "  \"exec\": \"{}\",", exec.name());
    let _ = writeln!(s, "  \"requests\": {},", args.requests);
    let _ = writeln!(s, "  \"clients\": {},", args.clients);
    let _ = writeln!(s, "  \"batch\": {},", args.batch);
    let _ = writeln!(s, "  \"max_wait_us\": {},", args.wait_us);
    let _ = writeln!(s, "  \"replicas\": {},", args.replicas);
    s.push_str("  \"models\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let rb: Vec<String> =
            r.report.replica_batches.iter().map(|b| b.to_string()).collect();
        let _ = write!(
            s,
            "    {{\"kind\": \"{}\", \"d_in\": {}, \"param_count\": {}, \"requests\": {}, \"batches\": {}, \"mean_fill\": {}, \"mean_queue_wait_ms\": {}, \"mean_exec_ms\": {}, \"p50_ms\": {}, \"p95_ms\": {}, \"p99_ms\": {}, \"throughput_rps\": {}, \"allocs_per_iter\": {}, \"replica_batches\": [{}]}}",
            r.kind.name(),
            r.d_in,
            r.params,
            r.report.requests,
            r.report.batches,
            json_num(r.report.mean_batch_fill),
            json_num(r.report.mean_queue_wait_ms),
            json_num(r.report.mean_exec_ms),
            json_num(r.report.p50_ms),
            json_num(r.report.p95_ms),
            json_num(r.report.p99_ms),
            json_num(r.report.throughput_rps),
            json_num(r.allocs_per_iter),
            rb.join(", ")
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// The CI gate: every architecture must have served EVERY request (the
/// old router could silently drop load), produced real throughput, and —
/// when replicas were requested and there was enough work — used every
/// replica. On the CI simd matrix leg (`SPM_EXEC=simd`) the vectorized
/// backend must actually be active: a detection or feature-wiring
/// regression fails the gate instead of silently serving through the
/// scalar fused path.
fn check_rows(rows: &[BenchRow], args: &Args) -> Result<(), String> {
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && !backend::simd_available() {
        return Err(
            "SPM_EXEC=simd but the simd backend did not activate (feature off or AVX2/FMA \
             undetected) — the serve smoke would only re-measure the fused path"
                .into(),
        );
    }
    for r in rows {
        let name = r.kind.name();
        if r.report.requests != args.requests {
            return Err(format!(
                "{name}: served {} of {} requests",
                r.report.requests, args.requests
            ));
        }
        if !(r.report.throughput_rps > 0.0) {
            return Err(format!("{name}: throughput {} req/s", r.report.throughput_rps));
        }
        if r.report.p99_ms < r.report.p50_ms {
            return Err(format!(
                "{name}: p99 {} < p50 {}",
                r.report.p99_ms, r.report.p50_ms
            ));
        }
        if r.report.batches >= 2 * args.replicas
            && r.report.replica_batches.iter().any(|&b| b == 0)
        {
            return Err(format!(
                "{name}: idle replica with {} batches across {:?}",
                r.report.batches, r.report.replica_batches
            ));
        }
        // the zero-allocation steady-state gate (DESIGN.md §15): a warm
        // executor micro-batch must not touch the allocator
        if r.allocs_per_iter != 0.0 {
            return Err(format!(
                "{name}: steady-state serve iteration allocated ({:.1} allocs/iter, want 0)",
                r.allocs_per_iter
            ));
        }
    }
    Ok(())
}

fn main() {
    let args = parse_args();
    let exec = serve_exec();
    println!(
        "serving engine: {} requests, {} clients, batch cap {}, deadline {} us, {} replica(s), exec {}\n",
        args.requests,
        args.clients,
        args.batch,
        args.wait_us,
        args.replicas,
        exec.name()
    );
    let rows: Vec<BenchRow> =
        ModelKind::ALL.iter().map(|&k| bench_kind(k, exec, &args)).collect();
    print_table(&rows);

    if let Some(path) = &args.json {
        std::fs::write(path, to_json(&rows, &args, exec))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote {path}");
    }

    if args.check {
        match check_rows(&rows, &args) {
            Ok(()) => println!(
                "\ncheck: all {} models served {}/{} requests with live replicas — OK",
                rows.len(),
                args.requests,
                args.requests
            ),
            Err(msg) => {
                eprintln!("check FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}
