//! Bench: the deadline-batched serving engine (DESIGN.md §13) over every
//! `ModelKind` — all four architectures through the same
//! `ServeEngine::native(model)` entry point, with replica sharding — and,
//! under `--gateway`, the closed-loop load generator for the TCP
//! front-end (DESIGN.md §16): interactive + batch lanes over loopback, a
//! mid-run checkpoint hot-swap, and a deliberate overload phase whose
//! shed-rate and p99 the `--check` gate enforces.
//!
//! Also buildable as an example (same file, see spm-coordinator's
//! Cargo.toml) so CI can drive reduced passes with plain `cargo run`:
//!
//! ```text
//! cargo run --release -p spm-coordinator --example serve_bench -- \
//!     --requests 97 --clients 4 --json BENCH_serve.json --check
//! cargo run --release -p spm-coordinator --example serve_bench -- \
//!     --gateway --requests 40 --clients 4 --json BENCH_gateway.json --check
//! ```
//!
//! Flags (shared parser: `spm_coordinator::bench_args`): `--requests N`
//! (default 256; per client per phase under `--gateway`), `--clients C`
//! (default 8), `--batch B` micro-batch cap (default 16), `--wait-us W`
//! interactive-lane deadline (default 200), `--replicas R` (default 2),
//! `--json <path>` machine-readable output (stamped with
//! `schema_version`), `--check` the CI gate. The gate's steady-phase
//! p99 budget comes from `[serve] p99_ms` in `ablate/gates.toml`
//! (DESIGN.md §17) and is enforced alongside zero steady sheds, a
//! hot-swap with zero dropped in-flight requests, and an overload
//! phase that MUST shed without a single engine failure.

use std::time::{Duration, Instant};

use spm_core::models::api::{build_model, save_checkpoint, ModelCfg, ModelKind};
use spm_core::ops::{backend, LinearCfg, SpmExec};
use spm_core::parallel;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_coordinator::ablate::Gates;
use spm_coordinator::allocs::{self, CountingAlloc};
use spm_coordinator::bench_args::{env_exec, json_header, json_num, BenchArgs};
use spm_coordinator::gateway::{Gateway, GatewayClient, InferOutcome};
use spm_coordinator::metrics::{fmt_f, summarize, Summary, Table};
// lint: allow(hygiene): Executor is imported for method resolution (`exec.forward`)
use spm_coordinator::serve::{
    Executor, Lane, NativeExecutor, ServeEngine, ServeReport, Shed, Workload,
};

// Count every allocator call so steady-state allocs_per_iter is a
// measured, gated number (DESIGN.md §15).
#[global_allocator]
static ALLOC_COUNTER: CountingAlloc = CountingAlloc;

struct Args {
    requests: usize,
    clients: usize,
    batch: usize,
    wait_us: u64,
    replicas: usize,
    gateway: bool,
    /// Steady-phase p99 budget: `[serve] p99_ms` from the gates schema.
    p99_ms: f64,
    json: Option<String>,
    check: bool,
}

fn parse_args(gates: &Gates) -> Args {
    let a = BenchArgs::parse();
    Args {
        requests: a.usize_flag("--requests", 256),
        clients: a.usize_flag("--clients", 8),
        batch: a.usize_flag("--batch", 16),
        wait_us: a.u64_flag("--wait-us", 200),
        replicas: a.usize_flag("--replicas", 2).max(1),
        gateway: a.has("--gateway"),
        p99_ms: gates.serve.p99_ms,
        json: a.json_path(),
        check: a.check(),
    }
}

/// The benched zoo: one small-but-real config per architecture. `exec`
/// selects the SPM stage-loop path on every owned op — the CI matrix
/// exports `SPM_EXEC` so the simd leg serves through the vectorized
/// backend instead of re-measuring the fused path under another name.
fn model_cfg(kind: ModelKind, exec: SpmExec) -> ModelCfg {
    let (n, heads, seq_len, classes) = match kind {
        ModelKind::Mlp => (64, 1, 1, 10),
        ModelKind::Gru => (32, 1, 8, 10),
        ModelKind::CharLm => (64, 1, 1, 0),
        ModelKind::Attention => (64, 4, 8, 0),
    };
    ModelCfg::new(kind, LinearCfg::spm(n, Variant::General))
        .with_classes(classes.max(2))
        .with_heads(heads)
        .with_seq_len(seq_len)
        .with_seed(7)
        .with_exec(exec)
}

struct BenchRow {
    kind: ModelKind,
    d_in: usize,
    params: usize,
    report: ServeReport,
    /// steady-state allocator calls per executor micro-batch on the
    /// router's batch-assembly ping-pong (DESIGN.md §15) — must be 0
    allocs_per_iter: f64,
}

/// One router iteration against a native executor, mimicking the serve
/// engine's batch-assembly ping-pong: take the pool, refill it with the
/// batch's rows, forward, keep the returned buffer as the next pool.
fn exec_iter(kind: ModelKind, exec: &mut NativeExecutor, rows: usize, pool: &mut Vec<f32>) {
    let width = exec.width();
    let mut flat = std::mem::take(pool);
    flat.clear();
    flat.resize(rows * width, 0.0);
    for (i, v) in flat.iter_mut().enumerate() {
        // charlm rows carry byte tokens, everything else small reals
        *v = match kind {
            ModelKind::CharLm => 97.0 + (i % 3) as f32,
            _ => ((i * 37 % 11) as f32) * 0.1 - 0.5,
        };
    }
    let out = exec.forward(rows, flat).expect("executor forward");
    *pool = out;
}

/// Measured steady-state allocs per served micro-batch: warm the
/// executor + pool pair, then count a batch-cap-sized iteration on one
/// thread (the engine's workers drive the identical path).
fn steady_allocs(kind: ModelKind, cfg: &ModelCfg, rows: usize) -> f64 {
    let mut exec = NativeExecutor::new(build_model(cfg), rows.max(1));
    let mut pool: Vec<f32> = Vec::new();
    parallel::with_thread_budget(1, || {
        for _ in 0..4 {
            exec_iter(kind, &mut exec, rows.max(1), &mut pool);
        }
        allocs::allocs_per_iter(4, || exec_iter(kind, &mut exec, rows.max(1), &mut pool))
    })
}

fn bench_kind(kind: ModelKind, exec: SpmExec, args: &Args) -> BenchRow {
    let cfg = model_cfg(kind, exec);
    let probe = build_model(&cfg);
    let (d_in, params) = (probe.d_in(), probe.param_count());
    let mut engine = ServeEngine::native(probe)
        .with_max_batch(args.batch)
        .with_max_wait_us(args.wait_us);
    for _ in 1..args.replicas {
        engine = engine.with_replica(build_model(&cfg));
    }
    let workload = Workload { num_requests: args.requests, num_clients: args.clients, seed: 11 };
    let report = engine
        .run(&workload)
        .unwrap_or_else(|e| panic!("{}: serve failed: {e}", kind.name()));
    let allocs_per_iter = steady_allocs(kind, &cfg, args.batch);
    BenchRow { kind, d_in, params, report, allocs_per_iter }
}

fn print_table(rows: &[BenchRow]) {
    let mut t = Table::new(&[
        "model",
        "d_in",
        "params",
        "requests",
        "batches",
        "fill",
        "queue ms",
        "exec ms",
        "p50 ms",
        "p99 ms",
        "req/s",
        "allocs/iter",
    ]);
    for r in rows {
        t.row(vec![
            r.kind.name().to_string(),
            r.d_in.to_string(),
            r.params.to_string(),
            r.report.requests.to_string(),
            r.report.batches.to_string(),
            fmt_f(r.report.mean_batch_fill, 1),
            fmt_f(r.report.mean_queue_wait_ms, 3),
            fmt_f(r.report.mean_exec_ms, 3),
            fmt_f(r.report.p50_ms, 3),
            fmt_f(r.report.p99_ms, 3),
            fmt_f(r.report.throughput_rps, 0),
            fmt_f(r.allocs_per_iter, 1),
        ]);
    }
    t.print();
}

/// Hand-rolled JSON (the default workspace is dependency-free): the run
/// setup plus one row per served model.
fn to_json(rows: &[BenchRow], args: &Args, exec: SpmExec) -> String {
    use std::fmt::Write as _;
    let mut s = json_header("serve");
    let _ = writeln!(s, "  \"exec\": \"{}\",", exec.name());
    let _ = writeln!(s, "  \"requests\": {},", args.requests);
    let _ = writeln!(s, "  \"clients\": {},", args.clients);
    let _ = writeln!(s, "  \"batch\": {},", args.batch);
    let _ = writeln!(s, "  \"max_wait_us\": {},", args.wait_us);
    let _ = writeln!(s, "  \"replicas\": {},", args.replicas);
    s.push_str("  \"models\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let rb: Vec<String> =
            r.report.replica_batches.iter().map(|b| b.to_string()).collect();
        let _ = write!(
            s,
            "    {{\"kind\": \"{}\", \"d_in\": {}, \"param_count\": {}, \"requests\": {}, \"submitted\": {}, \"shed_queue\": {}, \"shed_expired\": {}, \"failed\": {}, \"batches\": {}, \"mean_fill\": {}, \"mean_queue_wait_ms\": {}, \"mean_exec_ms\": {}, \"p50_ms\": {}, \"p95_ms\": {}, \"p99_ms\": {}, \"throughput_rps\": {}, \"allocs_per_iter\": {}, \"replica_batches\": [{}]}}",
            r.kind.name(),
            r.d_in,
            r.params,
            r.report.requests,
            r.report.submitted,
            r.report.shed_queue,
            r.report.shed_expired,
            r.report.failed,
            r.report.batches,
            json_num(r.report.mean_batch_fill),
            json_num(r.report.mean_queue_wait_ms),
            json_num(r.report.mean_exec_ms),
            json_num(r.report.p50_ms),
            json_num(r.report.p95_ms),
            json_num(r.report.p99_ms),
            json_num(r.report.throughput_rps),
            json_num(r.allocs_per_iter),
            rb.join(", ")
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// The CI gate: every architecture must have served EVERY request (the
/// old router could silently drop load), produced real throughput, and —
/// when replicas were requested and there was enough work — used every
/// replica. On the CI simd matrix leg (`SPM_EXEC=simd`) the vectorized
/// backend must actually be active: a detection or feature-wiring
/// regression fails the gate instead of silently serving through the
/// scalar fused path.
fn check_rows(rows: &[BenchRow], args: &Args, gates: &Gates) -> Result<(), String> {
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && !backend::simd_available() {
        return Err(
            "SPM_EXEC=simd but the simd backend did not activate (feature off or AVX2/FMA \
             undetected) — the serve smoke would only re-measure the fused path"
                .into(),
        );
    }
    for r in rows {
        let name = r.kind.name();
        if r.report.requests != args.requests {
            return Err(format!(
                "{name}: served {} of {} requests",
                r.report.requests, args.requests
            ));
        }
        if r.report.submitted != args.requests || r.report.shed() > 0 || r.report.failed > 0 {
            return Err(format!(
                "{name}: admission accounting broke — submitted {}, shed {}, failed {}",
                r.report.submitted,
                r.report.shed(),
                r.report.failed
            ));
        }
        if !(r.report.throughput_rps > 0.0) {
            return Err(format!("{name}: throughput {} req/s", r.report.throughput_rps));
        }
        if r.report.p99_ms < r.report.p50_ms {
            return Err(format!(
                "{name}: p99 {} < p50 {}",
                r.report.p99_ms, r.report.p50_ms
            ));
        }
        if r.report.batches >= 2 * args.replicas
            && r.report.replica_batches.iter().any(|&b| b == 0)
        {
            return Err(format!(
                "{name}: idle replica with {} batches across {:?}",
                r.report.batches, r.report.replica_batches
            ));
        }
        // the zero-allocation steady-state gate (DESIGN.md §15, cap from
        // the gates schema): a warm executor micro-batch must not touch
        // the allocator
        if r.allocs_per_iter > gates.serve.allocs_max {
            return Err(format!(
                "{name}: steady-state serve iteration allocated ({:.1} allocs/iter, cap {})",
                r.allocs_per_iter, gates.serve.allocs_max
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Gateway mode: the closed-loop load generator over loopback.
// ---------------------------------------------------------------------------

/// What one load-generator phase measured, wire-side.
struct PhaseRow {
    name: &'static str,
    submitted: usize,
    served: usize,
    shed_queue: usize,
    shed_expired: usize,
    failed: usize,
    latency: Summary,
    throughput_rps: f64,
    swaps_applied: usize,
    replicas: usize,
}

/// The serving model for gateway mode: the zoo's mlp (width 64).
fn gateway_model_cfg(exec: SpmExec, seed: u64) -> ModelCfg {
    model_cfg(ModelKind::Mlp, exec).with_seed(seed)
}

/// Closed-loop clients: each opens its own connection and issues its
/// share back-to-back (a reply triggers the next request), 3:1
/// interactive:batch. Returns per-request wire latencies (ms) and the
/// client-observed outcome counts.
fn drive_clients(
    addr: std::net::SocketAddr,
    width: usize,
    clients: usize,
    per_client: usize,
    deadline_us: u32,
) -> (Vec<f64>, usize, usize, usize) {
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = GatewayClient::connect(addr).expect("connect");
                let mut rng = Rng::new(0x6A7E ^ (c as u64 + 1) * 0x9E37);
                let mut lat = Vec::with_capacity(per_client);
                let (mut ok, mut shed) = (0usize, 0usize);
                for i in 0..per_client {
                    let lane = if i % 4 == 3 { Lane::Batch } else { Lane::Interactive };
                    let features = rng.normal_vec(width, 1.0);
                    let t0 = Instant::now();
                    match client.infer(lane, &features, deadline_us).expect("infer") {
                        InferOutcome::Ok(row) => {
                            assert!(!row.is_empty(), "empty output row");
                            lat.push(t0.elapsed().as_secs_f64() * 1e3);
                            ok += 1;
                        }
                        InferOutcome::Shed(Shed::EngineDown) => {
                            panic!("engine down mid-phase");
                        }
                        InferOutcome::Shed(_) => shed += 1,
                    }
                }
                (lat, ok, shed)
            })
        })
        .collect();
    let mut lat = Vec::new();
    let (mut ok, mut shed) = (0usize, 0usize);
    for w in workers {
        let (l, o, s) = w.join().expect("client panicked");
        lat.extend(l);
        ok += o;
        shed += s;
    }
    (lat, ok, shed, clients * per_client)
}

fn phase_row(
    name: &'static str,
    report: &ServeReport,
    mut lat: Vec<f64>,
    wall_secs: f64,
    replicas: usize,
) -> PhaseRow {
    PhaseRow {
        name,
        submitted: report.submitted,
        served: report.requests,
        shed_queue: report.shed_queue,
        shed_expired: report.shed_expired,
        failed: report.failed,
        latency: summarize(&mut lat),
        throughput_rps: report.requests as f64 / wall_secs.max(1e-9),
        swaps_applied: report.swaps_applied,
        replicas,
    }
}

/// Phase 1+2 share one gateway: a steady closed-loop pass, then the same
/// load with a checkpoint hot-swap fired mid-run from a separate
/// connection. Phase 3 runs its own gateway with tiny admission caps so
/// overload MUST shed.
fn run_gateway_bench(args: &Args, exec: SpmExec) -> Vec<PhaseRow> {
    let cfg = gateway_model_cfg(exec, 7);
    let build_engine = || {
        let mut engine = ServeEngine::native(build_model(&cfg))
            .with_max_batch(args.batch)
            .with_max_wait_us(args.wait_us);
        for _ in 1..args.replicas {
            engine = engine.with_replica(build_model(&cfg));
        }
        engine
    };
    let mut rows = Vec::new();

    // -- phase 1: steady state, unbounded queues — nothing may shed
    {
        let gw = Gateway::start(build_engine().start().expect("start"), "127.0.0.1:0")
            .expect("gateway");
        let width = gw.session().width();
        let t0 = Instant::now();
        let (lat, ok, shed, submitted) =
            drive_clients(gw.addr(), width, args.clients, args.requests, 0);
        let wall = t0.elapsed().as_secs_f64();
        let report = gw.stop().expect("stop");
        assert_eq!(
            (ok, shed, submitted),
            (report.requests, report.shed(), report.submitted),
            "wire-side and engine-side accounting must agree"
        );
        rows.push(phase_row("steady", &report, lat, wall, args.replicas));
    }

    // -- phase 2: the same load with a mid-run wire hot-swap
    {
        let gw = Gateway::start(build_engine().start().expect("start"), "127.0.0.1:0")
            .expect("gateway");
        let width = gw.session().width();
        // same arch (butterfly pairing is seed-independent), new params
        let swap_src = build_model(&gateway_model_cfg(exec, 13));
        let ckpt = std::env::temp_dir().join(format!("spm_gateway_bench_{}.ckpt", std::process::id()));
        save_checkpoint(swap_src.as_ref(), &ckpt).expect("save checkpoint");
        let image = std::fs::read(&ckpt).expect("read checkpoint");
        let _ = std::fs::remove_file(&ckpt);

        let addr = gw.addr();
        let swapper = std::thread::spawn(move || {
            // land mid-run: give the load a moment to ramp
            std::thread::sleep(Duration::from_millis(20));
            let mut c = GatewayClient::connect(addr).expect("swap connect");
            c.hot_swap(&image).expect("wire hot swap")
        });
        let t0 = Instant::now();
        let (lat, ok, shed, submitted) =
            drive_clients(gw.addr(), width, args.clients, args.requests, 0);
        let wall = t0.elapsed().as_secs_f64();
        let notified = swapper.join().expect("swapper panicked");
        assert_eq!(notified, args.replicas, "hot swap must reach every replica");
        let report = gw.stop().expect("stop");
        assert_eq!(
            (ok, shed, submitted),
            (report.requests, report.shed(), report.submitted),
            "wire-side and engine-side accounting must agree"
        );
        rows.push(phase_row("hotswap", &report, lat, wall, args.replicas));
    }

    // -- phase 3: deliberate overload — admission caps far below the
    // closed-loop client population, a long batching window to keep the
    // in-flight depth pinned high. Shedding here is the system WORKING.
    {
        let cap = (args.clients / 4).max(1);
        let engine = build_engine()
            .with_max_wait_us(5_000)
            .with_queue_depth(Lane::Interactive, cap)
            .with_queue_depth(Lane::Batch, cap);
        let gw = Gateway::start(engine.start().expect("start"), "127.0.0.1:0")
            .expect("gateway");
        let width = gw.session().width();
        let overload_clients = (args.clients * 2).max(cap + 2);
        let t0 = Instant::now();
        let (lat, ok, shed, submitted) =
            drive_clients(gw.addr(), width, overload_clients, args.requests, 0);
        let wall = t0.elapsed().as_secs_f64();
        let report = gw.stop().expect("stop");
        assert_eq!(
            (ok, shed, submitted),
            (report.requests, report.shed(), report.submitted),
            "wire-side and engine-side accounting must agree"
        );
        rows.push(phase_row("overload", &report, lat, wall, args.replicas));
    }

    rows
}

fn print_gateway_table(rows: &[PhaseRow]) {
    let mut t = Table::new(&[
        "phase",
        "submitted",
        "served",
        "shed q",
        "shed ddl",
        "failed",
        "shed %",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "req/s",
        "swaps",
    ]);
    for r in rows {
        let shed = r.shed_queue + r.shed_expired;
        t.row(vec![
            r.name.to_string(),
            r.submitted.to_string(),
            r.served.to_string(),
            r.shed_queue.to_string(),
            r.shed_expired.to_string(),
            r.failed.to_string(),
            fmt_f(100.0 * shed as f64 / r.submitted.max(1) as f64, 1),
            fmt_f(r.latency.p50, 3),
            fmt_f(r.latency.p95, 3),
            fmt_f(r.latency.p99, 3),
            fmt_f(r.throughput_rps, 0),
            r.swaps_applied.to_string(),
        ]);
    }
    t.print();
}

fn gateway_to_json(rows: &[PhaseRow], args: &Args, exec: SpmExec) -> String {
    use std::fmt::Write as _;
    let mut s = json_header("gateway");
    let _ = writeln!(s, "  \"exec\": \"{}\",", exec.name());
    let _ = writeln!(s, "  \"requests_per_client\": {},", args.requests);
    let _ = writeln!(s, "  \"clients\": {},", args.clients);
    let _ = writeln!(s, "  \"batch\": {},", args.batch);
    let _ = writeln!(s, "  \"max_wait_us\": {},", args.wait_us);
    let _ = writeln!(s, "  \"replicas\": {},", args.replicas);
    let _ = writeln!(s, "  \"p99_budget_ms\": {},", json_num(args.p99_ms));
    s.push_str("  \"phases\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let shed = r.shed_queue + r.shed_expired;
        let _ = write!(
            s,
            "    {{\"phase\": \"{}\", \"submitted\": {}, \"served\": {}, \"shed_queue\": {}, \"shed_expired\": {}, \"failed\": {}, \"shed_rate\": {}, \"p50_ms\": {}, \"p95_ms\": {}, \"p99_ms\": {}, \"throughput_rps\": {}, \"swaps_applied\": {}, \"replicas\": {}}}",
            r.name,
            r.submitted,
            r.served,
            r.shed_queue,
            r.shed_expired,
            r.failed,
            json_num(shed as f64 / r.submitted.max(1) as f64),
            json_num(r.latency.p50),
            json_num(r.latency.p95),
            json_num(r.latency.p99),
            json_num(r.throughput_rps),
            r.swaps_applied,
            r.replicas
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// The gateway CI gate (the ISSUE-7 acceptance bar):
/// - steady: zero sheds, zero failures, p99 within the `[serve] p99_ms` budget
/// - hotswap: every replica applied the swap and NOT ONE in-flight
///   request was dropped (served == submitted, failed == 0)
/// - overload: the gateway MUST shed (the admission queue works) while
///   still failing nothing and serving everything it admitted
fn check_gateway(rows: &[PhaseRow], args: &Args) -> Result<(), String> {
    if std::env::var("SPM_EXEC").as_deref() == Ok("simd") && !backend::simd_available() {
        return Err(
            "SPM_EXEC=simd but the simd backend did not activate (feature off or AVX2/FMA \
             undetected) — the gateway smoke would only re-measure the fused path"
                .into(),
        );
    }
    let get = |name: &str| {
        rows.iter().find(|r| r.name == name).ok_or_else(|| format!("missing phase '{name}'"))
    };
    let steady = get("steady")?;
    if steady.shed_queue + steady.shed_expired > 0 || steady.failed > 0 {
        return Err(format!(
            "steady phase shed/failed under no overload: shed {} + {}, failed {}",
            steady.shed_queue, steady.shed_expired, steady.failed
        ));
    }
    if steady.served != steady.submitted {
        return Err(format!(
            "steady phase dropped requests: served {} of {}",
            steady.served, steady.submitted
        ));
    }
    if steady.latency.p99 > args.p99_ms {
        return Err(format!(
            "steady p99 {:.3} ms blew the {:.0} ms budget",
            steady.latency.p99, args.p99_ms
        ));
    }
    let hotswap = get("hotswap")?;
    if hotswap.swaps_applied != args.replicas {
        return Err(format!(
            "hot swap reached {} of {} replicas",
            hotswap.swaps_applied, args.replicas
        ));
    }
    if hotswap.served != hotswap.submitted || hotswap.failed > 0 {
        return Err(format!(
            "hot swap dropped in-flight work: served {} of {}, failed {}",
            hotswap.served, hotswap.submitted, hotswap.failed
        ));
    }
    let overload = get("overload")?;
    if overload.shed_queue == 0 {
        return Err(
            "overload phase shed nothing — the admission queue cap is not engaging".into()
        );
    }
    if overload.failed > 0 {
        return Err(format!("overload phase failed {} requests", overload.failed));
    }
    if overload.served + overload.shed_queue + overload.shed_expired != overload.submitted {
        return Err(format!(
            "overload accounting leak: {} served + {} + {} shed != {} submitted",
            overload.served, overload.shed_queue, overload.shed_expired, overload.submitted
        ));
    }
    Ok(())
}

fn main() {
    let gates = Gates::load_default().unwrap_or_else(|e| {
        eprintln!("FAILED loading gates: {e}");
        std::process::exit(1);
    });
    let args = parse_args(&gates);
    let exec = env_exec();
    if args.check {
        println!("check thresholds: {}\n", gates.source);
    }

    if args.gateway {
        println!(
            "gateway load generator: {} requests/client, {} clients, batch cap {}, deadline {} us, {} replica(s), exec {}\n",
            args.requests, args.clients, args.batch, args.wait_us, args.replicas,
            exec.name()
        );
        let rows = run_gateway_bench(&args, exec);
        print_gateway_table(&rows);
        if let Some(path) = &args.json {
            std::fs::write(path, gateway_to_json(&rows, &args, exec))
                .unwrap_or_else(|e| panic!("writing {path}: {e}"));
            println!("\nwrote {path}");
        }
        if args.check {
            match check_gateway(&rows, &args) {
                Ok(()) => println!(
                    "\ncheck: steady p99 within budget, hot swap dropped nothing, overload shed — OK"
                ),
                Err(msg) => {
                    eprintln!("check FAILED: {msg}");
                    std::process::exit(1);
                }
            }
        }
        return;
    }

    println!(
        "serving engine: {} requests, {} clients, batch cap {}, deadline {} us, {} replica(s), exec {}\n",
        args.requests,
        args.clients,
        args.batch,
        args.wait_us,
        args.replicas,
        exec.name()
    );
    let rows: Vec<BenchRow> =
        ModelKind::ALL.iter().map(|&k| bench_kind(k, exec, &args)).collect();
    print_table(&rows);

    if let Some(path) = &args.json {
        std::fs::write(path, to_json(&rows, &args, exec))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote {path}");
    }

    if args.check {
        match check_rows(&rows, &args, &gates) {
            Ok(()) => println!(
                "\ncheck: all {} models served {}/{} requests with live replicas — OK",
                rows.len(),
                args.requests,
                args.requests
            ),
            Err(msg) => {
                eprintln!("check FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}
